//! Node-classification datasets (synthetic, statistics-matched).
//!
//! Paper Table 4 NC datasets: Cora, Citeseer, Pubmed, Ogbn-Arxiv,
//! Ogbn-Products, Ogbn-MAG, Ogbn-Papers100M. The real files are not
//! available offline, so each is generated with the *published* node /
//! feature / class counts and edge densities (see DESIGN.md §0 for why this
//! preserves the system benchmarks). `scale` uniformly shrinks a dataset for
//! fast tests while keeping feature/class dimensions — communication per
//! node is unchanged.

use crate::graph::{class_features, planted_graph, Csr, LazyGraph, PlantedSpec};
use crate::util::rng::Rng;

/// A materialized node-classification dataset.
pub struct NCDataset {
    pub name: String,
    pub graph: Csr,
    /// Row-major `[n, d]`.
    pub features: Vec<f32>,
    pub feat_dim: usize,
    pub labels: Vec<u16>,
    pub num_classes: usize,
    /// Node split: 0 = train, 1 = val, 2 = test.
    pub split: Vec<u8>,
}

impl NCDataset {
    pub fn n(&self) -> usize {
        self.graph.n
    }

    pub fn feature_row(&self, u: u32) -> &[f32] {
        &self.features[u as usize * self.feat_dim..(u as usize + 1) * self.feat_dim]
    }

    pub fn train_nodes(&self) -> Vec<u32> {
        (0..self.n() as u32).filter(|&u| self.split[u as usize] == 0).collect()
    }

    pub fn test_nodes(&self) -> Vec<u32> {
        (0..self.n() as u32).filter(|&u| self.split[u as usize] == 2).collect()
    }
}

/// Generation recipe for one dataset.
#[derive(Clone, Debug)]
pub struct NCSpec {
    pub name: &'static str,
    pub n: usize,
    pub feat_dim: usize,
    pub num_classes: usize,
    pub mean_degree: f64,
    pub homophily: f64,
    /// Feature signal strength (lower = harder task).
    pub signal: f32,
}

/// Published statistics for the paper's NC benchmarks.
pub const CORA: NCSpec = NCSpec {
    name: "cora-sim",
    n: 2708,
    feat_dim: 1433,
    num_classes: 7,
    mean_degree: 3.9, // 5429 undirected edges
    homophily: 0.81,
    signal: 0.45,
};

pub const CITESEER: NCSpec = NCSpec {
    name: "citeseer-sim",
    n: 3327,
    feat_dim: 3703,
    num_classes: 6,
    mean_degree: 2.8, // 4732 edges
    homophily: 0.74,
    signal: 0.22,
};

pub const PUBMED: NCSpec = NCSpec {
    name: "pubmed-sim",
    n: 19717,
    feat_dim: 500,
    num_classes: 3,
    mean_degree: 4.5, // 44338 edges
    homophily: 0.80,
    signal: 0.35,
};

pub const OGBN_ARXIV: NCSpec = NCSpec {
    name: "ogbn-arxiv-sim",
    n: 169_343,
    feat_dim: 128,
    num_classes: 40,
    mean_degree: 13.7, // 1.17M edges
    homophily: 0.65,
    signal: 0.8,
};

pub fn nc_specs() -> Vec<NCSpec> {
    vec![CORA, CITESEER, PUBMED, OGBN_ARXIV]
}

/// Look up a spec by dataset name ("cora-sim", "citeseer-sim", ...; the
/// plain paper names "cora" etc. are accepted as aliases).
pub fn nc_spec(name: &str) -> Option<NCSpec> {
    let canon = name.trim().to_lowercase();
    nc_specs().into_iter().find(|s| {
        s.name == canon || s.name.trim_end_matches("-sim") == canon
    })
}

/// Materialize a dataset at `scale` ∈ (0, 1] of its published node count.
/// Split is 60/20/20 train/val/test, stratified-free random (documented
/// deviation from Planetoid's tiny public splits: federated benchmarks
/// train on each client's own share, so percentage splits are the norm).
pub fn generate_nc(spec: &NCSpec, scale: f64, seed: u64) -> NCDataset {
    assert!(scale > 0.0 && scale <= 1.0);
    let n = ((spec.n as f64 * scale) as usize).max(64);
    let mut rng = Rng::seeded(seed ^ 0x4E43_5345_4544); // "NCSEED"
    let planted = PlantedSpec {
        n,
        num_classes: spec.num_classes,
        mean_degree: spec.mean_degree,
        homophily: spec.homophily,
        degree_skew: 2.5,
    };
    let (graph, labels) = planted_graph(&planted, &mut rng);
    let features = class_features(&labels, spec.num_classes, spec.feat_dim, spec.signal, &mut rng);
    let split = (0..n)
        .map(|_| {
            let r = rng.f64();
            if r < 0.6 {
                0
            } else if r < 0.8 {
                1
            } else {
                2
            }
        })
        .collect();
    NCDataset {
        name: spec.name.to_string(),
        graph,
        features,
        feat_dim: spec.feat_dim,
        labels,
        num_classes: spec.num_classes,
        split,
    }
}

/// The lazy 100M-node dataset (paper §5.3). Default parameters follow
/// Ogbn-Papers100M: 111M nodes, 128 features, 172 classes; `n` is
/// configurable so tests and benches can run the identical code path at
/// smaller scale.
pub fn papers100m_sim(n: u64, seed: u64) -> LazyGraph {
    LazyGraph::new(
        seed ^ 0x9A9E85,
        n,
        195 * 4, // communities; clients get several communities each
        172,
        128,
        14, // mean degree
        0.7,
        1.5,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cora_stats_match_published() {
        let ds = generate_nc(&CORA, 1.0, 7);
        assert_eq!(ds.n(), 2708);
        assert_eq!(ds.feat_dim, 1433);
        assert_eq!(ds.num_classes, 7);
        let edges = ds.graph.num_edges() as f64;
        // ~5429 published; generator targets mean degree 3.9 => ~5281
        assert!((4000.0..7000.0).contains(&edges), "cora edges {edges}");
        ds.graph.validate().unwrap();
    }

    #[test]
    fn scaling_shrinks_nodes_not_features() {
        let ds = generate_nc(&PUBMED, 0.05, 7);
        assert!(ds.n() < 1200 && ds.n() >= 64);
        assert_eq!(ds.feat_dim, 500);
    }

    #[test]
    fn split_fractions() {
        let ds = generate_nc(&CORA, 0.5, 3);
        let train = ds.train_nodes().len() as f64 / ds.n() as f64;
        let test = ds.test_nodes().len() as f64 / ds.n() as f64;
        assert!((train - 0.6).abs() < 0.06, "train {train}");
        assert!((test - 0.2).abs() < 0.05, "test {test}");
    }

    #[test]
    fn spec_lookup_aliases() {
        assert_eq!(nc_spec("cora").unwrap().name, "cora-sim");
        assert_eq!(nc_spec("Cora-Sim").unwrap().n, 2708);
        assert!(nc_spec("unknown").is_none());
    }

    #[test]
    fn papers100m_lazy_scales() {
        let g = papers100m_sim(1_000_000, 1);
        assert_eq!(g.n, 1_000_000);
        assert_eq!(g.num_classes, 172);
        assert_eq!(g.feat_dim, 128);
        // sampling a node's data is O(1)
        let mut buf = vec![0f32; 128];
        g.feature_into(999_999, &mut buf);
        assert!(g.label(0) < 172);
    }
}
