//! Dataset registry: statistics-matched synthetic counterparts of every
//! dataset in the paper's Table 4, plus the partition helpers the tasks use.
//!
//! Each task module ships two generation laws selected by the config's
//! `dataset_format`: v1 (sequential stream, bitwise-pinned legacy default)
//! and v2 (counter-based keyed streams — any entity's data is computable
//! O(local) from `(seed, entity id)`, so sliced workers generate only what
//! they own).

pub mod gc;
pub mod lp;
pub mod nc;

pub use gc::{
    gc_graph_count, gc_keyed_assign, gc_keyed_graph, gc_keyed_meta, gc_keyed_split, gc_spec,
    gc_specs, generate_gc, generate_gc_v2, GCDataset, GCSpec, SmallGraph, GC_FEAT_DIM,
};
pub use lp::{
    generate_lp, generate_lp_v2, lp_keyed_region, region_config, LPDataset, RegionData,
    LP_FEAT_DIM,
};
pub use nc::{
    generate_nc, keyed_he_ctx_seed, nc_spec, nc_specs, papers100m_sim, NCDataset, NCKeyedView,
    NCSpec,
};
