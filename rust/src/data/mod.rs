//! Dataset registry: statistics-matched synthetic counterparts of every
//! dataset in the paper's Table 4, plus the partition helpers the tasks use.

pub mod gc;
pub mod lp;
pub mod nc;

pub use gc::{gc_spec, gc_specs, generate_gc, GCDataset, GCSpec, SmallGraph, GC_FEAT_DIM};
pub use lp::{generate_lp, region_config, LPDataset, RegionData, LP_FEAT_DIM};
pub use nc::{generate_nc, nc_spec, nc_specs, papers100m_sim, NCDataset, NCSpec};
