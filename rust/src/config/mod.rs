//! Experiment configuration (the paper's access layer, Fig 2).
//!
//! A `FedGraphConfig` is everything `run_fedgraph` needs: task, method,
//! dataset, client/partition settings, training hyperparameters, privacy
//! options (HE / DP), the low-rank rank, and the simulated-network model.
//! Configs load from the YAML-subset parser (`util::yaml`) or are built in
//! code; task-method combinations are validated exactly as the paper's
//! library enforces them.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::he::{CkksParams, DpParams};
use crate::transport::NetConfig;
use crate::util::yaml::Yaml;

/// The three FGL tasks (paper Fig 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    NodeClassification,
    GraphClassification,
    LinkPrediction,
}

impl Task {
    pub fn parse(s: &str) -> Result<Task> {
        match s.trim().to_uppercase().as_str() {
            "NC" | "NODE_CLASSIFICATION" | "NODECLASSIFICATION" => Ok(Task::NodeClassification),
            "GC" | "GRAPH_CLASSIFICATION" | "GRAPHCLASSIFICATION" => Ok(Task::GraphClassification),
            "LP" | "LINK_PREDICTION" | "LINKPREDICTION" => Ok(Task::LinkPrediction),
            other => bail!("unknown fedgraph_task '{other}' (expected NC, GC or LP)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Task::NodeClassification => "NC",
            Task::GraphClassification => "GC",
            Task::LinkPrediction => "LP",
        }
    }
}

/// Every training algorithm in the paper's Table 5.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    // --- node classification ---
    FedAvgNC,
    DistributedGCN,
    BnsGcn,
    FedSagePlus,
    FedGcn,
    // --- graph classification ---
    SelfTrain,
    FedAvgGC,
    FedProx,
    Gcfl,
    GcflPlus,
    GcflPlusDws,
    // --- link prediction ---
    StaticGnn,
    Stfl,
    FedLink,
    FourDFedGnnPlus,
}

impl Method {
    pub fn parse(task: Task, s: &str) -> Result<Method> {
        let canon = s.trim().to_lowercase().replace('+', "plus").replace(['-', '_'], "");
        let m = match (task, canon.as_str()) {
            (Task::NodeClassification, "fedavg") => Method::FedAvgNC,
            (Task::NodeClassification, "distributedgcn" | "distgcn") => Method::DistributedGCN,
            (Task::NodeClassification, "bnsgcn") => Method::BnsGcn,
            (Task::NodeClassification, "fedsage" | "fedsageplus") => Method::FedSagePlus,
            (Task::NodeClassification, "fedgcn") => Method::FedGcn,
            (Task::GraphClassification, "selftrain") => Method::SelfTrain,
            (Task::GraphClassification, "fedavg") => Method::FedAvgGC,
            (Task::GraphClassification, "fedprox") => Method::FedProx,
            (Task::GraphClassification, "gcfl") => Method::Gcfl,
            (Task::GraphClassification, "gcflplus") => Method::GcflPlus,
            (Task::GraphClassification, "gcflplusdws" | "gcfldws") => Method::GcflPlusDws,
            (Task::LinkPrediction, "staticgnn") => Method::StaticGnn,
            (Task::LinkPrediction, "stfl") => Method::Stfl,
            (Task::LinkPrediction, "fedlink") => Method::FedLink,
            (Task::LinkPrediction, "4dfedgnn" | "4dfedgnnplus" | "fedgnnplus") => {
                Method::FourDFedGnnPlus
            }
            (t, other) => bail!(
                "method '{other}' is not valid for task {} (the library enforces \
                 explicit task-method combinations)",
                t.name()
            ),
        };
        Ok(m)
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::FedAvgNC => "FedAvg",
            Method::DistributedGCN => "DistributedGCN",
            Method::BnsGcn => "BNS-GCN",
            Method::FedSagePlus => "FedSage+",
            Method::FedGcn => "FedGCN",
            Method::SelfTrain => "SelfTrain",
            Method::FedAvgGC => "FedAvg",
            Method::FedProx => "FedProx",
            Method::Gcfl => "GCFL",
            Method::GcflPlus => "GCFL+",
            Method::GcflPlusDws => "GCFL+dWs",
            Method::StaticGnn => "StaticGNN",
            Method::Stfl => "STFL",
            Method::FedLink => "FedLink",
            Method::FourDFedGnnPlus => "4D-FED-GNN+",
        }
    }

    pub fn task(&self) -> Task {
        use Method::*;
        match self {
            FedAvgNC | DistributedGCN | BnsGcn | FedSagePlus | FedGcn => Task::NodeClassification,
            SelfTrain | FedAvgGC | FedProx | Gcfl | GcflPlus | GcflPlusDws => {
                Task::GraphClassification
            }
            StaticGnn | Stfl | FedLink | FourDFedGnnPlus => Task::LinkPrediction,
        }
    }
}

/// Client selection strategy (paper Appendix A.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SamplingType {
    Random,
    Uniform,
}

impl SamplingType {
    pub fn parse(s: &str) -> Result<SamplingType> {
        match s.trim().to_lowercase().as_str() {
            "random" => Ok(SamplingType::Random),
            "uniform" => Ok(SamplingType::Uniform),
            other => bail!("sampling_type must be either 'random' or 'uniform', got '{other}'"),
        }
    }
}

/// Privacy mechanism for aggregation.
#[derive(Clone, Debug, PartialEq)]
pub enum PrivacyMode {
    Plaintext,
    /// CKKS homomorphic encryption (paper §3.2).
    He(CkksParams),
    /// Gaussian-mechanism differential privacy (Appendix A.5).
    Dp(DpClone),
}

/// How the coordinator schedules a round (the [`crate::federation::policy::RoundPolicy`]
/// it instantiates).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FederationMode {
    /// One barrier per round: every participant's update is awaited before
    /// aggregation. Bitwise-identical to the sequential reference.
    Sync,
    /// Staleness-bounded buffered asynchrony (FedBuff-style): each scheduler
    /// step flushes after `buffer_size` fresh updates instead of waiting for
    /// stragglers; updates trained from a model more than `max_staleness`
    /// broadcasts old are rejected and ledgered as waste, admitted ones are
    /// re-weighted by `1 / (1 + staleness)`.
    Async,
}

impl FederationMode {
    pub fn parse(s: &str) -> Result<FederationMode> {
        match s.trim().to_lowercase().as_str() {
            "sync" => Ok(FederationMode::Sync),
            "async" => Ok(FederationMode::Async),
            other => bail!("federation.mode must be 'sync' or 'async', got '{other}'"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            FederationMode::Sync => "sync",
            FederationMode::Async => "async",
        }
    }
}

/// Federation-runtime settings (the `federation:` YAML block): how trainer
/// actors are scheduled and how client failures are injected.
#[derive(Clone, Debug, PartialEq)]
pub struct FederationConfig {
    /// Round scheduling policy: `sync` (barrier per round) or `async`
    /// (staleness-bounded buffered aggregation). Async requires plaintext or
    /// DP uploads and an aggregating, non-clustered method.
    pub mode: FederationMode,
    /// Async only: admit updates trained from a model at most this many
    /// broadcasts old; staler uploads are rejected (and their bytes ledgered
    /// as waste). `0` degenerates to the sync barrier — no client may be
    /// left behind — which is exactly how the equivalence test pins the
    /// policy refactor.
    pub max_staleness: u32,
    /// Async only: flush the aggregation buffer once this many fresh updates
    /// are in. `0` = auto (half the round's participants, at least one).
    pub buffer_size: usize,
    /// Worker shards for the coordinator's aggregation reduce. `0` = auto
    /// (one per core), `1` = the serial reference. Any value is
    /// bitwise-identical to serial (see `coordinator::aggregate`).
    pub agg_shards: usize,
    /// Max trainer actors computing at once. `0` = auto (one per selected
    /// client up to the machine's parallelism); `1` = the sequential
    /// reference execution (bitwise-identical results, serialized wall
    /// clock).
    pub max_concurrency: usize,
    /// Per-round probability that a selected client drops out before
    /// training (its round is skipped; aggregation re-weights over the
    /// survivors). `0.0` disables dropouts.
    pub dropout_frac: f64,
    /// Upper bound of a per-(round, client) deterministic straggler delay in
    /// milliseconds, injected into local training to model heterogeneous
    /// hardware. `0.0` disables stragglers.
    pub straggler_ms: f64,
}

impl Default for FederationConfig {
    fn default() -> Self {
        FederationConfig {
            mode: FederationMode::Sync,
            max_staleness: 1,
            buffer_size: 0,
            agg_shards: 0,
            max_concurrency: 0,
            dropout_frac: 0.0,
            straggler_ms: 0.0,
        }
    }
}

impl FederationConfig {
    /// Resolve `max_concurrency` for a round with `n` participants.
    pub fn resolved_concurrency(&self, n: usize) -> usize {
        let auto = || {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4)
        };
        let cap = if self.max_concurrency == 0 { auto() } else { self.max_concurrency };
        cap.min(n.max(1))
    }
}

/// DpParams is tiny; wrap for PartialEq.
#[derive(Clone, Debug)]
pub struct DpClone(pub DpParams);

impl PartialEq for DpClone {
    fn eq(&self, other: &Self) -> bool {
        self.0.epsilon == other.0.epsilon
            && self.0.delta == other.0.delta
            && self.0.clip_norm == other.0.clip_norm
    }
}

/// Full experiment configuration.
#[derive(Clone, Debug)]
pub struct FedGraphConfig {
    pub task: Task,
    pub method: Method,
    pub dataset: String,
    /// Number of trainers (clients).
    pub n_trainer: usize,
    pub global_rounds: usize,
    pub local_steps: usize,
    pub learning_rate: f32,
    /// Dirichlet concentration for the label-skew partition (β=10000 ≈ IID).
    pub iid_beta: f64,
    /// FedGCN communication hops (0 = none, 1, 2).
    pub num_hops: usize,
    /// Fraction of clients participating per round (Appendix A.1).
    pub sample_ratio: f64,
    pub sampling_type: SamplingType,
    /// Minibatch size in seed nodes (0 = full local graph).
    pub batch_size: usize,
    pub privacy: PrivacyMode,
    /// Low-rank pre-train compression rank (0 = off; paper §4).
    pub lowrank_rank: usize,
    /// BNS-GCN boundary-node sampling fraction.
    pub bns_ratio: f64,
    /// FedProx proximal coefficient μ.
    pub fedprox_mu: f32,
    /// Federation runtime: actor concurrency, dropouts, stragglers.
    pub federation: FederationConfig,
    pub network: NetConfig,
    pub seed: u64,
    /// Dataset scale factor (1.0 = published size).
    pub scale: f64,
    /// Where the AOT artifacts live.
    pub artifacts_dir: String,
    /// Evaluate every k rounds (test accuracy curve resolution).
    pub eval_every: usize,
    /// Free-form extras preserved from the YAML (forward compatibility).
    pub extras: BTreeMap<String, String>,
}

impl FedGraphConfig {
    /// A reasonable default for the given task/method/dataset (the paper's
    /// "10–20 lines" promise: most users only set these three).
    pub fn new(task: Task, method: Method, dataset: &str) -> Result<FedGraphConfig> {
        if method.task() != task {
            bail!(
                "method {} belongs to task {}, not {}",
                method.name(),
                method.task().name(),
                task.name()
            );
        }
        Ok(FedGraphConfig {
            task,
            method,
            dataset: dataset.to_string(),
            n_trainer: 10,
            global_rounds: 100,
            local_steps: 3,
            learning_rate: 0.1,
            iid_beta: 10_000.0,
            num_hops: if method == Method::FedGcn { 1 } else { 0 },
            sample_ratio: 1.0,
            sampling_type: SamplingType::Random,
            batch_size: 0,
            privacy: PrivacyMode::Plaintext,
            lowrank_rank: 0,
            bns_ratio: 0.5,
            fedprox_mu: 0.01,
            federation: FederationConfig::default(),
            network: NetConfig::default(),
            seed: 42,
            scale: 1.0,
            artifacts_dir: default_artifacts_dir(),
            eval_every: 1,
            extras: BTreeMap::new(),
        })
    }

    /// Parse from YAML text (see `configs/` for examples).
    pub fn parse_yaml(src: &str) -> Result<FedGraphConfig> {
        let y = Yaml::parse(src).map_err(|e| anyhow!("{e}"))?;
        Self::from_yaml(&y)
    }

    pub fn from_yaml_file(path: &str) -> Result<FedGraphConfig> {
        let src = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("cannot read config '{path}': {e}"))?;
        Self::parse_yaml(&src)
    }

    pub fn from_yaml(y: &Yaml) -> Result<FedGraphConfig> {
        let task = Task::parse(
            y.get("fedgraph_task")
                .as_str()
                .ok_or_else(|| anyhow!("missing required key 'fedgraph_task'"))?,
        )?;
        let method = Method::parse(
            task,
            y.get("method").as_str().ok_or_else(|| anyhow!("missing required key 'method'"))?,
        )?;
        let dataset = y
            .get("dataset")
            .as_str()
            .ok_or_else(|| anyhow!("missing required key 'dataset'"))?
            .to_string();
        let mut cfg = FedGraphConfig::new(task, method, &dataset)?;
        if let Some(v) = y.get("n_trainer").as_usize() {
            cfg.n_trainer = v;
        }
        if let Some(v) = y.get("global_rounds").as_usize() {
            cfg.global_rounds = v;
        }
        if let Some(v) = y.get("local_step").as_usize().or(y.get("local_steps").as_usize()) {
            cfg.local_steps = v;
        }
        if let Some(v) = y.get("learning_rate").as_f64() {
            cfg.learning_rate = v as f32;
        }
        if let Some(v) = y.get("iid_beta").as_f64() {
            cfg.iid_beta = v;
        }
        if let Some(v) = y.get("num_hops").as_usize() {
            cfg.num_hops = v;
        }
        if let Some(v) = y.get("sample_ratio").as_f64() {
            cfg.sample_ratio = v;
        }
        if let Some(s) = y.get("sampling_type").as_str() {
            cfg.sampling_type = SamplingType::parse(s)?;
        }
        if let Some(v) = y.get("batch_size").as_usize() {
            cfg.batch_size = v;
        }
        if let Some(v) = y.get("lowrank_rank").as_usize() {
            cfg.lowrank_rank = v;
        }
        if let Some(v) = y.get("bns_ratio").as_f64() {
            cfg.bns_ratio = v;
        }
        if let Some(v) = y.get("fedprox_mu").as_f64() {
            cfg.fedprox_mu = v as f32;
        }
        if let Some(v) = y.get("seed").as_usize() {
            cfg.seed = v as u64;
        }
        if let Some(v) = y.get("scale").as_f64() {
            cfg.scale = v;
        }
        if let Some(v) = y.get("eval_every").as_usize() {
            cfg.eval_every = v.max(1);
        }
        if let Some(s) = y.get("artifacts_dir").as_str() {
            cfg.artifacts_dir = s.to_string();
        }
        // Privacy block.
        let use_he = y.get("use_encryption").as_bool().unwrap_or(false);
        let use_dp = y.get("use_dp").as_bool().unwrap_or(false);
        if use_he && use_dp {
            bail!("use_encryption and use_dp are mutually exclusive");
        }
        if use_he {
            let mut params = CkksParams::default_params();
            let he = y.get("he");
            if let Some(v) = he.get("poly_modulus_degree").as_usize() {
                params = CkksParams::with_degree(v);
            }
            if let Some(list) = he.get("coeff_mod_bit_sizes").as_list() {
                params.coeff_mod_bits =
                    list.iter().filter_map(|x| x.as_usize().map(|v| v as u32)).collect();
            }
            if let Some(v) = he.get("scale_bits").as_usize() {
                params.scale_bits = v as u32;
            }
            cfg.privacy = PrivacyMode::He(params);
        } else if use_dp {
            let mut params = DpParams::default_params();
            let dp = y.get("dp");
            if let Some(v) = dp.get("epsilon").as_f64() {
                params.epsilon = v;
            }
            if let Some(v) = dp.get("delta").as_f64() {
                params.delta = v;
            }
            if let Some(v) = dp.get("clip_norm").as_f64() {
                params.clip_norm = v;
            }
            cfg.privacy = PrivacyMode::Dp(DpClone(params));
        }
        // Federation block.
        let fed = y.get("federation");
        if let Some(s) = fed.get("mode").as_str() {
            cfg.federation.mode = FederationMode::parse(s)?;
        }
        if let Some(v) = fed.get("max_staleness").as_usize() {
            cfg.federation.max_staleness = v as u32;
        }
        if let Some(v) = fed.get("buffer_size").as_usize() {
            cfg.federation.buffer_size = v;
        }
        if let Some(v) = fed.get("agg_shards").as_usize() {
            cfg.federation.agg_shards = v;
        }
        if let Some(v) = fed.get("max_concurrency").as_usize() {
            cfg.federation.max_concurrency = v;
        }
        if let Some(v) = fed.get("dropout_frac").as_f64() {
            cfg.federation.dropout_frac = v;
        }
        if let Some(v) = fed.get("straggler_ms").as_f64() {
            cfg.federation.straggler_ms = v;
        }
        // Network block.
        let net = y.get("network");
        if let Some(v) = net.get("bandwidth_gbps").as_f64() {
            cfg.network.bandwidth_gbps = v;
        }
        if let Some(v) = net.get("latency_ms").as_f64() {
            cfg.network.latency_ms = v;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Sanity-check the assembled config.
    pub fn validate(&self) -> Result<()> {
        if self.method.task() != self.task {
            bail!("method/task mismatch");
        }
        if self.n_trainer == 0 {
            bail!("n_trainer must be >= 1");
        }
        if !(0.0 < self.sample_ratio && self.sample_ratio <= 1.0) {
            bail!("sample_ratio must be in (0, 1], got {}", self.sample_ratio);
        }
        if self.num_hops > 2 {
            bail!("num_hops must be 0, 1 or 2");
        }
        if self.task != Task::NodeClassification && self.lowrank_rank != 0 {
            bail!("low-rank compression applies to the NC pre-train exchange only");
        }
        if !(self.scale > 0.0 && self.scale <= 1.0) {
            bail!("scale must be in (0, 1]");
        }
        if self.learning_rate <= 0.0 {
            bail!("learning_rate must be positive");
        }
        if !(0.0..1.0).contains(&self.federation.dropout_frac) {
            bail!(
                "federation.dropout_frac must be in [0, 1), got {}",
                self.federation.dropout_frac
            );
        }
        if self.federation.straggler_ms < 0.0 {
            bail!("federation.straggler_ms must be non-negative");
        }
        if self.federation.mode == FederationMode::Async {
            if self.uses_he() {
                bail!(
                    "federation.mode: async requires plaintext or DP uploads — staleness \
                     re-weighting cannot rescale CKKS ciphertexts"
                );
            }
            match self.method {
                Method::Gcfl | Method::GcflPlus | Method::GcflPlusDws => bail!(
                    "GCFL clustering reads every round's deltas in lockstep; \
                     use federation.mode: sync"
                ),
                Method::SelfTrain | Method::StaticGnn => bail!(
                    "{} never aggregates, so federation.mode: async has nothing to buffer",
                    self.method.name()
                ),
                _ => {}
            }
        }
        Ok(())
    }

    /// HE enabled?
    pub fn uses_he(&self) -> bool {
        matches!(self.privacy, PrivacyMode::He(_))
    }
}

/// Artifacts default to `<workspace>/artifacts` (next to Cargo.toml) so
/// examples and tests work from any cwd inside the repo.
pub fn default_artifacts_dir() -> String {
    let candidates = ["artifacts", "../artifacts", "../../artifacts"];
    for c in candidates {
        if std::path::Path::new(c).join("manifest.json").exists() {
            return c.to_string();
        }
    }
    // Fall back to the env override or the plain name.
    std::env::var("FEDGRAPH_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_default_config() {
        let cfg =
            FedGraphConfig::new(Task::NodeClassification, Method::FedGcn, "cora-sim").unwrap();
        assert_eq!(cfg.num_hops, 1);
        cfg.validate().unwrap();
    }

    #[test]
    fn task_method_combination_enforced() {
        // GCFL is a GC method; NC must reject it.
        assert!(Method::parse(Task::NodeClassification, "gcfl").is_err());
        assert!(Method::parse(Task::GraphClassification, "gcfl").is_ok());
        assert!(FedGraphConfig::new(Task::NodeClassification, Method::Gcfl, "x").is_err());
    }

    #[test]
    fn parses_paper_style_yaml() {
        let cfg = FedGraphConfig::parse_yaml(
            r#"
fedgraph_task: NC
dataset: cora-sim
method: FedGCN
global_rounds: 200
local_step: 3
learning_rate: 0.5
n_trainer: 10
num_hops: 1
iid_beta: 10000.0
use_encryption: true
he:
  poly_modulus_degree: 16384
  scale_bits: 40
network:
  bandwidth_gbps: 10.0
  latency_ms: 0.5
"#,
        )
        .unwrap();
        assert_eq!(cfg.method, Method::FedGcn);
        assert_eq!(cfg.global_rounds, 200);
        assert!(cfg.uses_he());
        assert_eq!(cfg.network.bandwidth_gbps, 10.0);
        if let PrivacyMode::He(p) = &cfg.privacy {
            assert_eq!(p.poly_mod_degree, 16384);
        }
    }

    #[test]
    fn parses_federation_block() {
        let cfg = FedGraphConfig::parse_yaml(
            r#"
fedgraph_task: NC
dataset: cora-sim
method: FedAvg
federation:
  max_concurrency: 4
  dropout_frac: 0.25
  straggler_ms: 20.0
"#,
        )
        .unwrap();
        assert_eq!(cfg.federation.max_concurrency, 4);
        assert_eq!(cfg.federation.dropout_frac, 0.25);
        assert_eq!(cfg.federation.straggler_ms, 20.0);
        // Defaults when the block is absent.
        let plain =
            FedGraphConfig::new(Task::NodeClassification, Method::FedAvgNC, "cora-sim").unwrap();
        assert_eq!(plain.federation, FederationConfig::default());
        // Resolution: explicit cap wins, never above the participant count.
        assert_eq!(cfg.federation.resolved_concurrency(2), 2);
        assert_eq!(cfg.federation.resolved_concurrency(100), 4);
        assert!(FederationConfig::default().resolved_concurrency(100) >= 1);
        // Bad dropout rejected.
        assert!(FedGraphConfig::parse_yaml(
            "fedgraph_task: NC\ndataset: x\nmethod: FedAvg\nfederation:\n  dropout_frac: 1.0\n"
        )
        .is_err());
    }

    #[test]
    fn parses_async_mode_block() {
        let cfg = FedGraphConfig::parse_yaml(
            r#"
fedgraph_task: NC
dataset: cora-sim
method: FedAvg
federation:
  mode: async
  max_staleness: 3
  buffer_size: 5
  agg_shards: 4
"#,
        )
        .unwrap();
        assert_eq!(cfg.federation.mode, FederationMode::Async);
        assert_eq!(cfg.federation.max_staleness, 3);
        assert_eq!(cfg.federation.buffer_size, 5);
        assert_eq!(cfg.federation.agg_shards, 4);
        // Defaults: sync barrier, auto buffer/shards.
        let plain =
            FedGraphConfig::new(Task::NodeClassification, Method::FedAvgNC, "cora-sim").unwrap();
        assert_eq!(plain.federation.mode, FederationMode::Sync);
        assert_eq!(plain.federation.buffer_size, 0, "0 = auto (resolved by the policy)");
        // Unknown mode string rejected.
        assert!(FedGraphConfig::parse_yaml(
            "fedgraph_task: NC\ndataset: x\nmethod: FedAvg\nfederation:\n  mode: chaotic\n"
        )
        .is_err());
    }

    #[test]
    fn async_mode_validation_guards() {
        // Async + HE: staleness re-weighting cannot rescale ciphertexts.
        assert!(FedGraphConfig::parse_yaml(
            "fedgraph_task: NC\ndataset: x\nmethod: FedAvg\nuse_encryption: true\n\
             federation:\n  mode: async\n"
        )
        .is_err());
        // Async + GCFL: clustering is lockstep.
        assert!(FedGraphConfig::parse_yaml(
            "fedgraph_task: GC\ndataset: x\nmethod: GCFL\nfederation:\n  mode: async\n"
        )
        .is_err());
        // Async + SelfTrain: nothing to buffer.
        assert!(FedGraphConfig::parse_yaml(
            "fedgraph_task: GC\ndataset: x\nmethod: SelfTrain\nfederation:\n  mode: async\n"
        )
        .is_err());
        // Async + DP is fine.
        assert!(FedGraphConfig::parse_yaml(
            "fedgraph_task: NC\ndataset: x\nmethod: FedAvg\nuse_dp: true\n\
             federation:\n  mode: async\n"
        )
        .is_ok());
    }

    #[test]
    fn rejects_invalid_configs() {
        assert!(FedGraphConfig::parse_yaml("dataset: x\nmethod: FedGCN\n").is_err()); // no task
        assert!(FedGraphConfig::parse_yaml(
            "fedgraph_task: NC\ndataset: x\nmethod: GCFL\n"
        )
        .is_err()); // wrong task-method
        assert!(FedGraphConfig::parse_yaml(
            "fedgraph_task: NC\ndataset: x\nmethod: FedGCN\nsample_ratio: 0.0\n"
        )
        .is_err());
        assert!(FedGraphConfig::parse_yaml(
            "fedgraph_task: NC\ndataset: x\nmethod: FedGCN\nuse_encryption: true\nuse_dp: true\n"
        )
        .is_err());
    }

    #[test]
    fn method_name_round_trip() {
        for (t, names) in [
            (Task::NodeClassification, vec!["FedAvg", "DistributedGCN", "BNS-GCN", "FedSage+", "FedGCN"]),
            (Task::GraphClassification, vec!["SelfTrain", "FedAvg", "FedProx", "GCFL", "GCFL+", "GCFL+dWs"]),
            (Task::LinkPrediction, vec!["StaticGNN", "STFL", "FedLink", "4D-FED-GNN+"]),
        ] {
            for n in names {
                let m = Method::parse(t, n).unwrap();
                assert_eq!(m.task(), t);
            }
        }
    }
}
