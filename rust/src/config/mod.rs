//! Experiment configuration (the paper's access layer, Fig 2).
//!
//! A `FedGraphConfig` is everything `run_fedgraph` needs: task, method,
//! dataset, client/partition settings, training hyperparameters, privacy
//! options (HE / DP), the low-rank rank, and the simulated-network model.
//! Configs load from the YAML-subset parser (`util::yaml`) or are built in
//! code; task-method combinations are validated exactly as the paper's
//! library enforces them.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::he::{CkksParams, DpParams};
use crate::transport::serialize::{Reader, WireError, Writer};
use crate::transport::NetConfig;
use crate::util::yaml::Yaml;

/// The three FGL tasks (paper Fig 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    NodeClassification,
    GraphClassification,
    LinkPrediction,
}

impl Task {
    pub fn parse(s: &str) -> Result<Task> {
        match s.trim().to_uppercase().as_str() {
            "NC" | "NODE_CLASSIFICATION" | "NODECLASSIFICATION" => Ok(Task::NodeClassification),
            "GC" | "GRAPH_CLASSIFICATION" | "GRAPHCLASSIFICATION" => Ok(Task::GraphClassification),
            "LP" | "LINK_PREDICTION" | "LINKPREDICTION" => Ok(Task::LinkPrediction),
            other => bail!("unknown fedgraph_task '{other}' (expected NC, GC or LP)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Task::NodeClassification => "NC",
            Task::GraphClassification => "GC",
            Task::LinkPrediction => "LP",
        }
    }
}

/// Every training algorithm in the paper's Table 5.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    // --- node classification ---
    FedAvgNC,
    DistributedGCN,
    BnsGcn,
    FedSagePlus,
    FedGcn,
    // --- graph classification ---
    SelfTrain,
    FedAvgGC,
    FedProx,
    Gcfl,
    GcflPlus,
    GcflPlusDws,
    // --- link prediction ---
    StaticGnn,
    Stfl,
    FedLink,
    FourDFedGnnPlus,
}

impl Method {
    pub fn parse(task: Task, s: &str) -> Result<Method> {
        let canon = s.trim().to_lowercase().replace('+', "plus").replace(['-', '_'], "");
        let m = match (task, canon.as_str()) {
            (Task::NodeClassification, "fedavg") => Method::FedAvgNC,
            (Task::NodeClassification, "distributedgcn" | "distgcn") => Method::DistributedGCN,
            (Task::NodeClassification, "bnsgcn") => Method::BnsGcn,
            (Task::NodeClassification, "fedsage" | "fedsageplus") => Method::FedSagePlus,
            (Task::NodeClassification, "fedgcn") => Method::FedGcn,
            (Task::GraphClassification, "selftrain") => Method::SelfTrain,
            (Task::GraphClassification, "fedavg") => Method::FedAvgGC,
            (Task::GraphClassification, "fedprox") => Method::FedProx,
            (Task::GraphClassification, "gcfl") => Method::Gcfl,
            (Task::GraphClassification, "gcflplus") => Method::GcflPlus,
            (Task::GraphClassification, "gcflplusdws" | "gcfldws") => Method::GcflPlusDws,
            (Task::LinkPrediction, "staticgnn") => Method::StaticGnn,
            (Task::LinkPrediction, "stfl") => Method::Stfl,
            (Task::LinkPrediction, "fedlink") => Method::FedLink,
            (Task::LinkPrediction, "4dfedgnn" | "4dfedgnnplus" | "fedgnnplus") => {
                Method::FourDFedGnnPlus
            }
            (t, other) => bail!(
                "method '{other}' is not valid for task {} (the library enforces \
                 explicit task-method combinations)",
                t.name()
            ),
        };
        Ok(m)
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::FedAvgNC => "FedAvg",
            Method::DistributedGCN => "DistributedGCN",
            Method::BnsGcn => "BNS-GCN",
            Method::FedSagePlus => "FedSage+",
            Method::FedGcn => "FedGCN",
            Method::SelfTrain => "SelfTrain",
            Method::FedAvgGC => "FedAvg",
            Method::FedProx => "FedProx",
            Method::Gcfl => "GCFL",
            Method::GcflPlus => "GCFL+",
            Method::GcflPlusDws => "GCFL+dWs",
            Method::StaticGnn => "StaticGNN",
            Method::Stfl => "STFL",
            Method::FedLink => "FedLink",
            Method::FourDFedGnnPlus => "4D-FED-GNN+",
        }
    }

    pub fn task(&self) -> Task {
        use Method::*;
        match self {
            FedAvgNC | DistributedGCN | BnsGcn | FedSagePlus | FedGcn => Task::NodeClassification,
            SelfTrain | FedAvgGC | FedProx | Gcfl | GcflPlus | GcflPlusDws => {
                Task::GraphClassification
            }
            StaticGnn | Stfl | FedLink | FourDFedGnnPlus => Task::LinkPrediction,
        }
    }
}

/// Client selection strategy (paper Appendix A.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SamplingType {
    Random,
    Uniform,
}

impl SamplingType {
    pub fn parse(s: &str) -> Result<SamplingType> {
        match s.trim().to_lowercase().as_str() {
            "random" => Ok(SamplingType::Random),
            "uniform" => Ok(SamplingType::Uniform),
            other => bail!("sampling_type must be either 'random' or 'uniform', got '{other}'"),
        }
    }
}

/// Which generation law synthesizes the datasets (`dataset_format`).
///
/// The two formats produce *statistically matched but bitwise different*
/// datasets, so the knob is versioned like a file format:
///
/// - **v1** (default for one release): the original sequential-stream
///   generators. Sliced builds stay bitwise-identical to full builds by
///   replaying or [`crate::util::rng::Rng::skip`]-ping past every unowned
///   client's draws — correctness at O(total-nodes) generation cost per
///   worker.
/// - **v2**: counter-based keyed generation
///   ([`crate::util::rng::CounterRng`]): every entity draws from its own
///   `(seed, domain, entity-id)` stream, so a sliced worker generates
///   **only its assigned entities** (O(assigned-nodes) work and memory,
///   no replay, no skip) and is bitwise-identical to the matching slice of
///   a v2 full build by construction.
///
/// Golden checksums for both formats are pinned in
/// `rust/tests/golden/dataset_checksums.json` (see `data::golden` tests).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatasetFormat {
    V1,
    V2,
}

impl DatasetFormat {
    pub fn parse(s: &str) -> Result<DatasetFormat> {
        match s.trim().to_lowercase().as_str() {
            "v1" | "1" => Ok(DatasetFormat::V1),
            "v2" | "2" => Ok(DatasetFormat::V2),
            other => bail!("dataset_format must be 'v1' or 'v2', got '{other}'"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DatasetFormat::V1 => "v1",
            DatasetFormat::V2 => "v2",
        }
    }
}

/// Privacy mechanism for aggregation.
#[derive(Clone, Debug, PartialEq)]
pub enum PrivacyMode {
    Plaintext,
    /// CKKS homomorphic encryption (paper §3.2).
    He(CkksParams),
    /// Gaussian-mechanism differential privacy (Appendix A.5).
    Dp(DpClone),
}

/// How the coordinator schedules a round (the [`crate::federation::policy::RoundPolicy`]
/// it instantiates).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FederationMode {
    /// One barrier per round: every participant's update is awaited before
    /// aggregation. Bitwise-identical to the sequential reference.
    Sync,
    /// Staleness-bounded buffered asynchrony (FedBuff-style): each scheduler
    /// step flushes after `buffer_size` fresh updates instead of waiting for
    /// stragglers; updates trained from a model more than `max_staleness`
    /// broadcasts old are rejected and ledgered as waste, admitted ones are
    /// re-weighted by `1 / (1 + staleness)`.
    Async,
}

impl FederationMode {
    pub fn parse(s: &str) -> Result<FederationMode> {
        match s.trim().to_lowercase().as_str() {
            "sync" => Ok(FederationMode::Sync),
            "async" => Ok(FederationMode::Async),
            other => bail!("federation.mode must be 'sync' or 'async', got '{other}'"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            FederationMode::Sync => "sync",
            FederationMode::Async => "async",
        }
    }
}

/// Upload wire codec (`federation.compression`): how model updates are
/// encoded before they cross a transport. See `docs/CONFIG.md` and
/// `docs/WIRE_FORMAT.md` for the full semantics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CompressionMode {
    /// Ship plaintext f32 values unchanged (default).
    None,
    /// Lossless: delta-encode the upload against the version-stamped
    /// broadcast the client trained from, then byte-plane pack the delta
    /// (XOR planes + zero-RLE). Bitwise-transparent — params, accuracy and
    /// the SimNet ledger are identical to `none`; only measured wire bytes
    /// shrink.
    Pack,
    /// Lossy, opt-in: per-chunk affine int8/int4 quantization of the upload
    /// delta with deterministic dequantization and (by default) client-side
    /// error-feedback residuals. Pairs with plaintext/DP uploads only —
    /// ciphertexts cannot be delta-quantized (validated).
    Quantized { bits: u8, error_feedback: bool },
}

impl CompressionMode {
    pub fn parse(s: &str) -> Result<CompressionMode> {
        match s.trim().to_lowercase().as_str() {
            "none" | "off" => Ok(CompressionMode::None),
            "pack" => Ok(CompressionMode::Pack),
            "quantized" | "quantize" | "quant" => {
                Ok(CompressionMode::Quantized { bits: 8, error_feedback: true })
            }
            other => bail!(
                "federation.compression must be 'none', 'pack' or 'quantized', got '{other}'"
            ),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            CompressionMode::None => "none",
            CompressionMode::Pack => "pack",
            CompressionMode::Quantized { .. } => "quantized",
        }
    }

    /// Whether decoding an upload under this codec needs the broadcast it
    /// was trained from (the coordinator keeps a version-keyed window of
    /// recent broadcasts when true).
    pub fn needs_base(&self) -> bool {
        !matches!(self, CompressionMode::None)
    }
}

/// Optional entropy stage behind the byte-plane pack codec
/// (`federation.entropy`): whether the RLE token streams of packed payloads
/// — uplink `pack` and downlink `SetModelPacked` alike — additionally pass
/// through the static-model rANS coder. Lossless either way; only measured
/// wire bytes change.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EntropyMode {
    /// Ship the RLE token streams as-is (default).
    None,
    /// rANS-entropy-code each byte plane's RLE stream with a per-plane
    /// frequency table in the blob header. Requires `compression: pack`.
    Rans,
}

impl EntropyMode {
    pub fn parse(s: &str) -> Result<EntropyMode> {
        match s.trim().to_lowercase().as_str() {
            "none" | "off" => Ok(EntropyMode::None),
            "rans" => Ok(EntropyMode::Rans),
            other => bail!("federation.entropy must be 'none' or 'rans', got '{other}'"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            EntropyMode::None => "none",
            EntropyMode::Rans => "rans",
        }
    }
}

/// Which transport backend carries the federation's protocol frames — i.e.
/// where the trainer actors live.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process deployment: trainer actors are OS threads and frames move
    /// through `std::sync::mpsc` channels (the default; bitwise-identical
    /// reference).
    Channel,
    /// Multi-process deployment: the coordinator listens on
    /// `federation.listen_addr` and `federation.workers` separate
    /// `fedgraph worker` processes host the trainer actors over
    /// length-prefixed, checksummed socket frames.
    Tcp,
}

impl TransportKind {
    pub fn parse(s: &str) -> Result<TransportKind> {
        match s.trim().to_lowercase().as_str() {
            "channel" | "inprocess" | "in-process" => Ok(TransportKind::Channel),
            "tcp" | "socket" => Ok(TransportKind::Tcp),
            other => bail!("federation.transport must be 'channel' or 'tcp', got '{other}'"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::Channel => "channel",
            TransportKind::Tcp => "tcp",
        }
    }
}

/// Federation-runtime settings (the `federation:` YAML block): how trainer
/// actors are scheduled, where they are deployed, and how client failures
/// are injected.
#[derive(Clone, Debug, PartialEq)]
pub struct FederationConfig {
    /// Transport backend: `channel` (threads in this process) or `tcp`
    /// (separate worker processes over sockets).
    pub transport: TransportKind,
    /// TCP only: the coordinator's listen address (`host:port`; port 0 binds
    /// an ephemeral port).
    pub listen_addr: String,
    /// TCP only: how many worker processes the coordinator waits for before
    /// the rendezvous. Clients are assigned round-robin over the workers in
    /// accept order.
    pub workers: usize,
    /// Round scheduling policy: `sync` (barrier per round) or `async`
    /// (staleness-bounded buffered aggregation). Async requires plaintext or
    /// DP uploads and an aggregating, non-clustered method.
    pub mode: FederationMode,
    /// Async only: admit updates trained from a model at most this many
    /// broadcasts old; staler uploads are rejected (and their bytes ledgered
    /// as waste). `0` degenerates to the sync barrier — no client may be
    /// left behind — which is exactly how the equivalence test pins the
    /// policy refactor.
    pub max_staleness: u32,
    /// Async only: flush the aggregation buffer once this many fresh updates
    /// are in. `0` = auto (half the round's participants, at least one).
    pub buffer_size: usize,
    /// Worker shards for the coordinator's aggregation reduce. `0` = auto
    /// (one per core), `1` = the serial reference. Any value is
    /// bitwise-identical to serial (see `coordinator::aggregate`).
    pub agg_shards: usize,
    /// Max trainer actors computing at once **per process**. `0` = auto (one
    /// per selected client up to the machine's parallelism); `1` = the
    /// sequential reference execution (bitwise-identical results, serialized
    /// wall clock). In a `tcp` deployment the cap applies independently in
    /// every worker process — each worker models its own machine's cores —
    /// so total concurrency is up to `workers × max_concurrency`; results
    /// stay bitwise-identical regardless, but measured compute/wait timings
    /// are only comparable across deployments at matching effective
    /// parallelism.
    pub max_concurrency: usize,
    /// Per-round probability that a selected client drops out before
    /// training (its round is skipped; aggregation re-weights over the
    /// survivors). `0.0` disables dropouts.
    pub dropout_frac: f64,
    /// Upper bound of a per-(round, client) deterministic straggler delay in
    /// milliseconds, injected into local training to model heterogeneous
    /// hardware. `0.0` disables stragglers.
    pub straggler_ms: f64,
    /// Wire codec: `none` (raw f32 frames), `pack` (lossless delta +
    /// byte-plane packing in **both directions** — compressed uploads and
    /// `SetModelPacked` downlink broadcasts — bitwise-transparent), or
    /// `quantized` (lossy int8/int4 upload-delta quantization with error
    /// feedback; plaintext/DP sessions only; broadcasts stay raw). The YAML
    /// keys `quantized_bits` and `error_feedback` refine the quantized mode.
    pub compression: CompressionMode,
    /// Optional rANS entropy stage behind the pack codec (both directions).
    /// `none` (default) ships plain RLE streams; `rans` requires
    /// `compression: pack` (validated).
    pub entropy: EntropyMode,
    /// Failure detection and recovery knobs (TCP deployments; see
    /// `docs/FAULT_TOLERANCE.md`).
    pub fault_tolerance: FaultToleranceConfig,
}

/// Fault-tolerance settings (`federation.fault_tolerance:` YAML block).
/// TCP deployments only; the in-process channel transport has no partial
/// failures to detect.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultToleranceConfig {
    /// Interval (ms) at which each worker process writes an empty control
    /// heartbeat frame so the coordinator can tell a slow worker from a dead
    /// one. `0` disables heartbeats.
    pub heartbeat_ms: u64,
    /// Silence window (ms) after which the coordinator declares a worker
    /// connection dead (`WorkerGone`) and re-assigns its clients to the
    /// survivors. Also bounds the post-connect `WorkerHello` handshake read.
    /// `0` disables liveness timeouts entirely (socket EOF / checksum
    /// failures still trigger recovery).
    pub worker_timeout_ms: u64,
    /// Take a `RoundCheckpoint` snapshot every this many rounds at the round
    /// boundary (`0` = off). Checkpoints feed late-join assignments and the
    /// resumable-coordinator restore path.
    pub checkpoint_every: u64,
    /// Directory for durable checkpoint persistence (empty = in-memory
    /// only). With `checkpoint_every > 0`, every snapshot is also committed
    /// to this directory through the atomic file store
    /// (`federation::store::FileCheckpointStore`), and `fedgraph run
    /// --resume <dir>` boots a fresh coordinator process from the newest
    /// valid file.
    pub checkpoint_dir: String,
    /// How long (ms) the coordinator waits for a dead worker connection to
    /// *reconnect* (re-handshaking with its session token) before firing the
    /// full recovery re-deal. `0` disables the grace window: every lane loss
    /// is treated as a process death, exactly as before.
    pub reconnect_grace_ms: u64,
    /// First retry delay (ms) of the worker's capped, jittered exponential
    /// connect/reconnect backoff.
    pub connect_retry_base_ms: u64,
    /// Upper bound (ms) on a single backoff delay.
    pub connect_retry_cap_ms: u64,
    /// Total time budget (ms) across all connect attempts before the worker
    /// gives up with a typed `ConnectTimeout` error.
    pub connect_retry_budget_ms: u64,
}

impl Default for FaultToleranceConfig {
    fn default() -> Self {
        FaultToleranceConfig {
            heartbeat_ms: 500,
            worker_timeout_ms: 10_000,
            checkpoint_every: 0,
            checkpoint_dir: String::new(),
            reconnect_grace_ms: 0,
            connect_retry_base_ms: 100,
            connect_retry_cap_ms: 2_000,
            connect_retry_budget_ms: 30_000,
        }
    }
}

impl Default for FederationConfig {
    fn default() -> Self {
        FederationConfig {
            transport: TransportKind::Channel,
            listen_addr: "127.0.0.1:8791".to_string(),
            workers: 2,
            mode: FederationMode::Sync,
            max_staleness: 1,
            buffer_size: 0,
            agg_shards: 0,
            max_concurrency: 0,
            dropout_frac: 0.0,
            straggler_ms: 0.0,
            compression: CompressionMode::None,
            entropy: EntropyMode::None,
            fault_tolerance: FaultToleranceConfig::default(),
        }
    }
}

impl FederationConfig {
    /// Resolve `max_concurrency` for a round with `n` participants.
    pub fn resolved_concurrency(&self, n: usize) -> usize {
        let auto = || {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4)
        };
        let cap = if self.max_concurrency == 0 { auto() } else { self.max_concurrency };
        cap.min(n.max(1))
    }
}

/// DpParams is tiny; wrap for PartialEq.
#[derive(Clone, Debug)]
pub struct DpClone(pub DpParams);

impl PartialEq for DpClone {
    fn eq(&self, other: &Self) -> bool {
        self.0.epsilon == other.0.epsilon
            && self.0.delta == other.0.delta
            && self.0.clip_norm == other.0.clip_norm
    }
}

/// Full experiment configuration.
#[derive(Clone, Debug)]
pub struct FedGraphConfig {
    pub task: Task,
    pub method: Method,
    pub dataset: String,
    /// Number of trainers (clients).
    pub n_trainer: usize,
    pub global_rounds: usize,
    pub local_steps: usize,
    pub learning_rate: f32,
    /// Dirichlet concentration for the label-skew partition (β=10000 ≈ IID).
    pub iid_beta: f64,
    /// FedGCN communication hops (0 = none, 1, 2).
    pub num_hops: usize,
    /// Fraction of clients participating per round (Appendix A.1).
    pub sample_ratio: f64,
    pub sampling_type: SamplingType,
    /// Minibatch size in seed nodes (0 = full local graph).
    pub batch_size: usize,
    pub privacy: PrivacyMode,
    /// Low-rank pre-train compression rank (0 = off; paper §4).
    pub lowrank_rank: usize,
    /// BNS-GCN boundary-node sampling fraction.
    pub bns_ratio: f64,
    /// FedProx proximal coefficient μ.
    pub fedprox_mu: f32,
    /// Federation runtime: actor concurrency, dropouts, stragglers.
    pub federation: FederationConfig,
    pub network: NetConfig,
    pub seed: u64,
    /// Dataset scale factor (1.0 = published size).
    pub scale: f64,
    /// Dataset generation law: `v1` (sequential streams, the bitwise-pinned
    /// default) or `v2` (counter-based keyed generation with O(assigned-
    /// nodes) sliced builds). See [`DatasetFormat`].
    pub dataset_format: DatasetFormat,
    /// Where the AOT artifacts live.
    pub artifacts_dir: String,
    /// Evaluate every k rounds (test accuracy curve resolution).
    pub eval_every: usize,
    /// Free-form extras preserved from the YAML (forward compatibility).
    pub extras: BTreeMap<String, String>,
}

impl FedGraphConfig {
    /// A reasonable default for the given task/method/dataset (the paper's
    /// "10–20 lines" promise: most users only set these three).
    pub fn new(task: Task, method: Method, dataset: &str) -> Result<FedGraphConfig> {
        if method.task() != task {
            bail!(
                "method {} belongs to task {}, not {}",
                method.name(),
                method.task().name(),
                task.name()
            );
        }
        Ok(FedGraphConfig {
            task,
            method,
            dataset: dataset.to_string(),
            n_trainer: 10,
            global_rounds: 100,
            local_steps: 3,
            learning_rate: 0.1,
            iid_beta: 10_000.0,
            num_hops: if method == Method::FedGcn { 1 } else { 0 },
            sample_ratio: 1.0,
            sampling_type: SamplingType::Random,
            batch_size: 0,
            privacy: PrivacyMode::Plaintext,
            lowrank_rank: 0,
            bns_ratio: 0.5,
            fedprox_mu: 0.01,
            federation: FederationConfig::default(),
            network: NetConfig::default(),
            seed: 42,
            scale: 1.0,
            dataset_format: DatasetFormat::V1,
            artifacts_dir: default_artifacts_dir(),
            eval_every: 1,
            extras: BTreeMap::new(),
        })
    }

    /// Is flight-recorder span tracing on for this run? Carried in `extras`
    /// (`trace: "1"`, set by the CLI's `--trace` flag or YAML extras), so it
    /// rides the bit-exact config wire encoding to worker processes without
    /// a config-wire version bump. Tracing is pure observation: enabling it
    /// changes no run result (see [`crate::trace`]).
    pub fn trace_enabled(&self) -> bool {
        self.extras.get("trace").map(|v| v == "1").unwrap_or(false)
    }

    /// Parse from YAML text (see `configs/` for examples).
    pub fn parse_yaml(src: &str) -> Result<FedGraphConfig> {
        let y = Yaml::parse(src).map_err(|e| anyhow!("{e}"))?;
        Self::from_yaml(&y)
    }

    pub fn from_yaml_file(path: &str) -> Result<FedGraphConfig> {
        let src = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("cannot read config '{path}': {e}"))?;
        Self::parse_yaml(&src)
    }

    pub fn from_yaml(y: &Yaml) -> Result<FedGraphConfig> {
        let task = Task::parse(
            y.get("fedgraph_task")
                .as_str()
                .ok_or_else(|| anyhow!("missing required key 'fedgraph_task'"))?,
        )?;
        let method = Method::parse(
            task,
            y.get("method").as_str().ok_or_else(|| anyhow!("missing required key 'method'"))?,
        )?;
        let dataset = y
            .get("dataset")
            .as_str()
            .ok_or_else(|| anyhow!("missing required key 'dataset'"))?
            .to_string();
        let mut cfg = FedGraphConfig::new(task, method, &dataset)?;
        if let Some(v) = y.get("n_trainer").as_usize() {
            cfg.n_trainer = v;
        }
        if let Some(v) = y.get("global_rounds").as_usize() {
            cfg.global_rounds = v;
        }
        if let Some(v) = y.get("local_step").as_usize().or(y.get("local_steps").as_usize()) {
            cfg.local_steps = v;
        }
        if let Some(v) = y.get("learning_rate").as_f64() {
            cfg.learning_rate = v as f32;
        }
        if let Some(v) = y.get("iid_beta").as_f64() {
            cfg.iid_beta = v;
        }
        if let Some(v) = y.get("num_hops").as_usize() {
            cfg.num_hops = v;
        }
        if let Some(v) = y.get("sample_ratio").as_f64() {
            cfg.sample_ratio = v;
        }
        if let Some(s) = y.get("sampling_type").as_str() {
            cfg.sampling_type = SamplingType::parse(s)?;
        }
        if let Some(v) = y.get("batch_size").as_usize() {
            cfg.batch_size = v;
        }
        if let Some(v) = y.get("lowrank_rank").as_usize() {
            cfg.lowrank_rank = v;
        }
        if let Some(v) = y.get("bns_ratio").as_f64() {
            cfg.bns_ratio = v;
        }
        if let Some(v) = y.get("fedprox_mu").as_f64() {
            cfg.fedprox_mu = v as f32;
        }
        if let Some(v) = y.get("seed").as_usize() {
            cfg.seed = v as u64;
        }
        if let Some(v) = y.get("scale").as_f64() {
            cfg.scale = v;
        }
        if let Some(s) = y.get("dataset_format").as_str() {
            cfg.dataset_format = DatasetFormat::parse(s)?;
        }
        if let Some(v) = y.get("eval_every").as_usize() {
            cfg.eval_every = v.max(1);
        }
        if let Some(s) = y.get("artifacts_dir").as_str() {
            cfg.artifacts_dir = s.to_string();
        }
        // Privacy block.
        let use_he = y.get("use_encryption").as_bool().unwrap_or(false);
        let use_dp = y.get("use_dp").as_bool().unwrap_or(false);
        if use_he && use_dp {
            bail!("use_encryption and use_dp are mutually exclusive");
        }
        if use_he {
            let mut params = CkksParams::default_params();
            let he = y.get("he");
            if let Some(v) = he.get("poly_modulus_degree").as_usize() {
                params = CkksParams::with_degree(v);
            }
            if let Some(list) = he.get("coeff_mod_bit_sizes").as_list() {
                params.coeff_mod_bits =
                    list.iter().filter_map(|x| x.as_usize().map(|v| v as u32)).collect();
            }
            if let Some(v) = he.get("scale_bits").as_usize() {
                params.scale_bits = v as u32;
            }
            cfg.privacy = PrivacyMode::He(params);
        } else if use_dp {
            let mut params = DpParams::default_params();
            let dp = y.get("dp");
            if let Some(v) = dp.get("epsilon").as_f64() {
                params.epsilon = v;
            }
            if let Some(v) = dp.get("delta").as_f64() {
                params.delta = v;
            }
            if let Some(v) = dp.get("clip_norm").as_f64() {
                params.clip_norm = v;
            }
            cfg.privacy = PrivacyMode::Dp(DpClone(params));
        }
        // Federation block.
        let fed = y.get("federation");
        if let Some(s) = fed.get("transport").as_str() {
            cfg.federation.transport = TransportKind::parse(s)?;
        }
        if let Some(s) = fed.get("listen_addr").as_str() {
            cfg.federation.listen_addr = s.to_string();
        }
        if let Some(v) = fed.get("workers").as_usize() {
            cfg.federation.workers = v;
        }
        if let Some(s) = fed.get("mode").as_str() {
            cfg.federation.mode = FederationMode::parse(s)?;
        }
        if let Some(v) = fed.get("max_staleness").as_usize() {
            cfg.federation.max_staleness = v as u32;
        }
        if let Some(v) = fed.get("buffer_size").as_usize() {
            cfg.federation.buffer_size = v;
        }
        if let Some(v) = fed.get("agg_shards").as_usize() {
            cfg.federation.agg_shards = v;
        }
        if let Some(v) = fed.get("max_concurrency").as_usize() {
            cfg.federation.max_concurrency = v;
        }
        if let Some(v) = fed.get("dropout_frac").as_f64() {
            cfg.federation.dropout_frac = v;
        }
        if let Some(v) = fed.get("straggler_ms").as_f64() {
            cfg.federation.straggler_ms = v;
        }
        if let Some(s) = fed.get("compression").as_str() {
            cfg.federation.compression = CompressionMode::parse(s)?;
        }
        if let CompressionMode::Quantized { mut bits, mut error_feedback } =
            cfg.federation.compression
        {
            if let Some(v) = fed.get("quantized_bits").as_usize() {
                bits = v as u8;
            }
            if let Some(b) = fed.get("error_feedback").as_bool() {
                error_feedback = b;
            }
            cfg.federation.compression = CompressionMode::Quantized { bits, error_feedback };
        }
        if let Some(s) = fed.get("entropy").as_str() {
            cfg.federation.entropy = EntropyMode::parse(s)?;
        }
        let ft = fed.get("fault_tolerance");
        if let Some(v) = ft.get("heartbeat_ms").as_usize() {
            cfg.federation.fault_tolerance.heartbeat_ms = v as u64;
        }
        if let Some(v) = ft.get("worker_timeout_ms").as_usize() {
            cfg.federation.fault_tolerance.worker_timeout_ms = v as u64;
        }
        if let Some(v) = ft.get("checkpoint_every").as_usize() {
            cfg.federation.fault_tolerance.checkpoint_every = v as u64;
        }
        if let Some(s) = ft.get("checkpoint_dir").as_str() {
            cfg.federation.fault_tolerance.checkpoint_dir = s.to_string();
        }
        if let Some(v) = ft.get("reconnect_grace_ms").as_usize() {
            cfg.federation.fault_tolerance.reconnect_grace_ms = v as u64;
        }
        if let Some(v) = ft.get("connect_retry_base_ms").as_usize() {
            cfg.federation.fault_tolerance.connect_retry_base_ms = v as u64;
        }
        if let Some(v) = ft.get("connect_retry_cap_ms").as_usize() {
            cfg.federation.fault_tolerance.connect_retry_cap_ms = v as u64;
        }
        if let Some(v) = ft.get("connect_retry_budget_ms").as_usize() {
            cfg.federation.fault_tolerance.connect_retry_budget_ms = v as u64;
        }
        // Network block.
        let net = y.get("network");
        if let Some(v) = net.get("bandwidth_gbps").as_f64() {
            cfg.network.bandwidth_gbps = v;
        }
        if let Some(v) = net.get("latency_ms").as_f64() {
            cfg.network.latency_ms = v;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Sanity-check the assembled config.
    pub fn validate(&self) -> Result<()> {
        if self.method.task() != self.task {
            bail!("method/task mismatch");
        }
        if self.n_trainer == 0 {
            bail!("n_trainer must be >= 1");
        }
        if !(0.0 < self.sample_ratio && self.sample_ratio <= 1.0) {
            bail!("sample_ratio must be in (0, 1], got {}", self.sample_ratio);
        }
        if self.num_hops > 2 {
            bail!("num_hops must be 0, 1 or 2");
        }
        if self.task != Task::NodeClassification && self.lowrank_rank != 0 {
            bail!("low-rank compression applies to the NC pre-train exchange only");
        }
        if !(self.scale > 0.0 && self.scale <= 1.0) {
            bail!("scale must be in (0, 1]");
        }
        if self.learning_rate <= 0.0 {
            bail!("learning_rate must be positive");
        }
        if !(0.0..1.0).contains(&self.federation.dropout_frac) {
            bail!(
                "federation.dropout_frac must be in [0, 1), got {}",
                self.federation.dropout_frac
            );
        }
        if self.federation.straggler_ms < 0.0 {
            bail!("federation.straggler_ms must be non-negative");
        }
        if self.federation.transport == TransportKind::Tcp {
            if self.federation.workers == 0 {
                bail!("federation.transport: tcp needs federation.workers >= 1");
            }
            if self.federation.listen_addr.is_empty() {
                bail!("federation.transport: tcp needs a federation.listen_addr");
            }
        }
        {
            let ft = &self.federation.fault_tolerance;
            if ft.worker_timeout_ms > 0 && ft.heartbeat_ms == 0 {
                bail!(
                    "federation.fault_tolerance.worker_timeout_ms > 0 needs heartbeat_ms > 0 — \
                     without heartbeats an idle-but-alive worker would be declared dead"
                );
            }
            if ft.worker_timeout_ms > 0 && ft.worker_timeout_ms < 2 * ft.heartbeat_ms {
                bail!(
                    "federation.fault_tolerance.worker_timeout_ms ({}) must be at least twice \
                     heartbeat_ms ({}) so one delayed heartbeat cannot kill a live worker",
                    ft.worker_timeout_ms,
                    ft.heartbeat_ms
                );
            }
            if !ft.checkpoint_dir.is_empty() && ft.checkpoint_every == 0 {
                bail!(
                    "federation.fault_tolerance.checkpoint_dir is set but checkpoint_every is 0 \
                     — nothing would ever be persisted; set checkpoint_every >= 1"
                );
            }
            if ft.connect_retry_base_ms == 0 {
                bail!("federation.fault_tolerance.connect_retry_base_ms must be >= 1");
            }
            if ft.connect_retry_cap_ms < ft.connect_retry_base_ms {
                bail!(
                    "federation.fault_tolerance.connect_retry_cap_ms ({}) must be >= \
                     connect_retry_base_ms ({})",
                    ft.connect_retry_cap_ms,
                    ft.connect_retry_base_ms
                );
            }
        }
        if let CompressionMode::Quantized { bits, .. } = self.federation.compression {
            if bits != 4 && bits != 8 {
                bail!(
                    "federation.quantized_bits must be 4 or 8, got {bits} (the codec ships \
                     nibble- or byte-wide codes)"
                );
            }
            if self.uses_he() {
                bail!(
                    "federation.compression: quantized requires plaintext or DP uploads — \
                     CKKS ciphertexts cannot be delta-quantized (use 'pack'-free HE, or drop \
                     use_encryption)"
                );
            }
        }
        if self.federation.entropy == EntropyMode::Rans
            && self.federation.compression != CompressionMode::Pack
        {
            bail!(
                "federation.entropy: rans is a stage behind the byte-plane pack codec — \
                 it requires federation.compression: pack (got '{}')",
                self.federation.compression.name()
            );
        }
        if self.federation.mode == FederationMode::Async {
            if self.uses_he() {
                bail!(
                    "federation.mode: async requires plaintext or DP uploads — staleness \
                     re-weighting cannot rescale CKKS ciphertexts"
                );
            }
            match self.method {
                Method::Gcfl | Method::GcflPlus | Method::GcflPlusDws => bail!(
                    "GCFL clustering reads every round's deltas in lockstep; \
                     use federation.mode: sync"
                ),
                Method::SelfTrain | Method::StaticGnn => bail!(
                    "{} never aggregates, so federation.mode: async has nothing to buffer",
                    self.method.name()
                ),
                _ => {}
            }
        }
        Ok(())
    }

    /// HE enabled?
    pub fn uses_he(&self) -> bool {
        matches!(self.privacy, PrivacyMode::He(_))
    }

    /// Serialize the full config to checksummed wire bytes — the body of the
    /// multi-process handshake's `Assign` frame. Binary (not YAML) so every
    /// float reaches the worker process bit-exact: workers rebuild their
    /// datasets, partitions and RNG streams from this config, and the
    /// deployment guarantee is that a TCP run is bitwise-identical to the
    /// in-process run.
    pub fn encode_wire(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.u8(CONFIG_WIRE_VERSION);
        w.u8(task_code(self.task));
        w.u8(method_code(self.method));
        w.str(&self.dataset);
        w.u64(self.n_trainer as u64);
        w.u64(self.global_rounds as u64);
        w.u64(self.local_steps as u64);
        w.f32(self.learning_rate);
        w.f64(self.iid_beta);
        w.u64(self.num_hops as u64);
        w.f64(self.sample_ratio);
        w.u8(match self.sampling_type {
            SamplingType::Random => 0,
            SamplingType::Uniform => 1,
        });
        w.u64(self.batch_size as u64);
        match &self.privacy {
            PrivacyMode::Plaintext => w.u8(0),
            PrivacyMode::He(p) => {
                w.u8(1);
                w.u64(p.poly_mod_degree as u64);
                w.u32(p.coeff_mod_bits.len() as u32);
                for &b in &p.coeff_mod_bits {
                    w.u32(b);
                }
                w.u32(p.scale_bits);
                w.u32(p.security_level);
            }
            PrivacyMode::Dp(d) => {
                w.u8(2);
                w.f64(d.0.epsilon);
                w.f64(d.0.delta);
                w.f64(d.0.clip_norm);
            }
        }
        w.u64(self.lowrank_rank as u64);
        w.f64(self.bns_ratio);
        w.f32(self.fedprox_mu);
        let f = &self.federation;
        w.u8(match f.transport {
            TransportKind::Channel => 0,
            TransportKind::Tcp => 1,
        });
        w.str(&f.listen_addr);
        w.u64(f.workers as u64);
        w.u8(match f.mode {
            FederationMode::Sync => 0,
            FederationMode::Async => 1,
        });
        w.u32(f.max_staleness);
        w.u64(f.buffer_size as u64);
        w.u64(f.agg_shards as u64);
        w.u64(f.max_concurrency as u64);
        w.f64(f.dropout_frac);
        w.f64(f.straggler_ms);
        match f.compression {
            CompressionMode::None => {
                w.u8(0);
                w.u8(0);
                w.u8(0);
            }
            CompressionMode::Pack => {
                w.u8(1);
                w.u8(0);
                w.u8(0);
            }
            CompressionMode::Quantized { bits, error_feedback } => {
                w.u8(2);
                w.u8(bits);
                w.u8(error_feedback as u8);
            }
        }
        w.u8(match f.entropy {
            EntropyMode::None => 0,
            EntropyMode::Rans => 1,
        });
        w.u64(f.fault_tolerance.heartbeat_ms);
        w.u64(f.fault_tolerance.worker_timeout_ms);
        w.u64(f.fault_tolerance.checkpoint_every);
        w.str(&f.fault_tolerance.checkpoint_dir);
        w.u64(f.fault_tolerance.reconnect_grace_ms);
        w.u64(f.fault_tolerance.connect_retry_base_ms);
        w.u64(f.fault_tolerance.connect_retry_cap_ms);
        w.u64(f.fault_tolerance.connect_retry_budget_ms);
        w.f64(self.network.bandwidth_gbps);
        w.f64(self.network.latency_ms);
        w.u64(self.seed);
        w.f64(self.scale);
        w.u8(match self.dataset_format {
            DatasetFormat::V1 => 1,
            DatasetFormat::V2 => 2,
        });
        w.str(&self.artifacts_dir);
        w.u64(self.eval_every as u64);
        w.u32(self.extras.len() as u32);
        for (k, v) in &self.extras {
            w.str(k);
            w.str(v);
        }
        w.finish()
    }

    /// Inverse of [`FedGraphConfig::encode_wire`].
    pub fn decode_wire(bytes: &[u8]) -> Result<FedGraphConfig> {
        let mut r = Reader::open(bytes).map_err(|e| anyhow!("config wire: {e}"))?;
        let mut next = || -> Result<FedGraphConfig, WireError> {
            let version = r.u8()?;
            if version != CONFIG_WIRE_VERSION {
                // An old coordinator talking to a new worker (or vice versa).
                return Err(WireError::BadTag(version));
            }
            let task = task_from_code(r.u8()?)?;
            let method = method_from_code(r.u8()?)?;
            let dataset = r.str()?;
            let mut cfg = FedGraphConfig::new(task, method, &dataset)
                .map_err(|_| WireError::BadTag(0))?;
            cfg.n_trainer = r.u64()? as usize;
            cfg.global_rounds = r.u64()? as usize;
            cfg.local_steps = r.u64()? as usize;
            cfg.learning_rate = r.f32()?;
            cfg.iid_beta = r.f64()?;
            cfg.num_hops = r.u64()? as usize;
            cfg.sample_ratio = r.f64()?;
            cfg.sampling_type = match r.u8()? {
                0 => SamplingType::Random,
                _ => SamplingType::Uniform,
            };
            cfg.batch_size = r.u64()? as usize;
            cfg.privacy = match r.u8()? {
                0 => PrivacyMode::Plaintext,
                1 => {
                    let degree = r.u64()? as usize;
                    let n_bits = r.u32()? as usize;
                    let mut coeff = Vec::with_capacity(n_bits);
                    for _ in 0..n_bits {
                        coeff.push(r.u32()?);
                    }
                    let mut p = CkksParams::with_degree(degree);
                    p.coeff_mod_bits = coeff;
                    p.scale_bits = r.u32()?;
                    p.security_level = r.u32()?;
                    PrivacyMode::He(p)
                }
                2 => PrivacyMode::Dp(DpClone(DpParams {
                    epsilon: r.f64()?,
                    delta: r.f64()?,
                    clip_norm: r.f64()?,
                })),
                t => return Err(WireError::BadTag(t)),
            };
            cfg.lowrank_rank = r.u64()? as usize;
            cfg.bns_ratio = r.f64()?;
            cfg.fedprox_mu = r.f32()?;
            cfg.federation.transport = match r.u8()? {
                0 => TransportKind::Channel,
                _ => TransportKind::Tcp,
            };
            cfg.federation.listen_addr = r.str()?;
            cfg.federation.workers = r.u64()? as usize;
            cfg.federation.mode = match r.u8()? {
                0 => FederationMode::Sync,
                _ => FederationMode::Async,
            };
            cfg.federation.max_staleness = r.u32()?;
            cfg.federation.buffer_size = r.u64()? as usize;
            cfg.federation.agg_shards = r.u64()? as usize;
            cfg.federation.max_concurrency = r.u64()? as usize;
            cfg.federation.dropout_frac = r.f64()?;
            cfg.federation.straggler_ms = r.f64()?;
            cfg.federation.compression = {
                let mode = r.u8()?;
                let bits = r.u8()?;
                let error_feedback = r.u8()? != 0;
                match mode {
                    0 => CompressionMode::None,
                    1 => CompressionMode::Pack,
                    2 => CompressionMode::Quantized { bits, error_feedback },
                    t => return Err(WireError::BadTag(t)),
                }
            };
            cfg.federation.entropy = match r.u8()? {
                0 => EntropyMode::None,
                1 => EntropyMode::Rans,
                t => return Err(WireError::BadTag(t)),
            };
            cfg.federation.fault_tolerance.heartbeat_ms = r.u64()?;
            cfg.federation.fault_tolerance.worker_timeout_ms = r.u64()?;
            cfg.federation.fault_tolerance.checkpoint_every = r.u64()?;
            cfg.federation.fault_tolerance.checkpoint_dir = r.str()?;
            cfg.federation.fault_tolerance.reconnect_grace_ms = r.u64()?;
            cfg.federation.fault_tolerance.connect_retry_base_ms = r.u64()?;
            cfg.federation.fault_tolerance.connect_retry_cap_ms = r.u64()?;
            cfg.federation.fault_tolerance.connect_retry_budget_ms = r.u64()?;
            cfg.network.bandwidth_gbps = r.f64()?;
            cfg.network.latency_ms = r.f64()?;
            cfg.seed = r.u64()?;
            cfg.scale = r.f64()?;
            cfg.dataset_format = match r.u8()? {
                1 => DatasetFormat::V1,
                2 => DatasetFormat::V2,
                t => return Err(WireError::BadTag(t)),
            };
            cfg.artifacts_dir = r.str()?;
            cfg.eval_every = r.u64()? as usize;
            let n_extras = r.u32()? as usize;
            for _ in 0..n_extras {
                let k = r.str()?;
                let v = r.str()?;
                cfg.extras.insert(k, v);
            }
            Ok(cfg)
        };
        let cfg = next().map_err(|e| anyhow!("config wire: {e}"))?;
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Bumped whenever [`FedGraphConfig::encode_wire`] changes shape, so a
/// mismatched coordinator/worker pair fails the handshake loudly instead of
/// mis-parsing. v2: `federation.compression` (upload codec) joined the
/// federation block. v3: `dataset_format` (dataset generation law) joined —
/// a worker must build the *same format* dataset the coordinator did, so
/// the knob rides the bit-exact wire config rather than defaulting.
/// v4: `federation.entropy` (rANS stage behind the pack codec, both
/// directions) joined the federation block.
/// v5: `federation.fault_tolerance` (heartbeat/timeout/checkpoint cadence)
/// joined the federation block — workers must agree on the heartbeat
/// interval the coordinator's liveness window assumes.
/// v6: durable-elasticity keys joined `fault_tolerance` — `checkpoint_dir`
/// (file-store root), `reconnect_grace_ms` (coordinator-side reconnect
/// window), and the worker's `connect_retry_{base,cap,budget}_ms` backoff
/// schedule, which rides the wire so a respawned worker retries on the
/// same schedule the supervisor assumed.
pub const CONFIG_WIRE_VERSION: u8 = 6;

fn task_code(t: Task) -> u8 {
    match t {
        Task::NodeClassification => 0,
        Task::GraphClassification => 1,
        Task::LinkPrediction => 2,
    }
}

fn task_from_code(c: u8) -> Result<Task, WireError> {
    Ok(match c {
        0 => Task::NodeClassification,
        1 => Task::GraphClassification,
        2 => Task::LinkPrediction,
        t => return Err(WireError::BadTag(t)),
    })
}

fn method_code(m: Method) -> u8 {
    use Method::*;
    match m {
        FedAvgNC => 0,
        DistributedGCN => 1,
        BnsGcn => 2,
        FedSagePlus => 3,
        FedGcn => 4,
        SelfTrain => 5,
        FedAvgGC => 6,
        FedProx => 7,
        Gcfl => 8,
        GcflPlus => 9,
        GcflPlusDws => 10,
        StaticGnn => 11,
        Stfl => 12,
        FedLink => 13,
        FourDFedGnnPlus => 14,
    }
}

fn method_from_code(c: u8) -> Result<Method, WireError> {
    use Method::*;
    Ok(match c {
        0 => FedAvgNC,
        1 => DistributedGCN,
        2 => BnsGcn,
        3 => FedSagePlus,
        4 => FedGcn,
        5 => SelfTrain,
        6 => FedAvgGC,
        7 => FedProx,
        8 => Gcfl,
        9 => GcflPlus,
        10 => GcflPlusDws,
        11 => StaticGnn,
        12 => Stfl,
        13 => FedLink,
        14 => FourDFedGnnPlus,
        t => return Err(WireError::BadTag(t)),
    })
}

/// Artifacts default to `<workspace>/artifacts` (next to Cargo.toml) so
/// examples and tests work from any cwd inside the repo.
pub fn default_artifacts_dir() -> String {
    let candidates = ["artifacts", "../artifacts", "../../artifacts"];
    for c in candidates {
        if std::path::Path::new(c).join("manifest.json").exists() {
            return c.to_string();
        }
    }
    // Fall back to the env override or the plain name.
    std::env::var("FEDGRAPH_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_default_config() {
        let cfg =
            FedGraphConfig::new(Task::NodeClassification, Method::FedGcn, "cora-sim").unwrap();
        assert_eq!(cfg.num_hops, 1);
        cfg.validate().unwrap();
    }

    #[test]
    fn task_method_combination_enforced() {
        // GCFL is a GC method; NC must reject it.
        assert!(Method::parse(Task::NodeClassification, "gcfl").is_err());
        assert!(Method::parse(Task::GraphClassification, "gcfl").is_ok());
        assert!(FedGraphConfig::new(Task::NodeClassification, Method::Gcfl, "x").is_err());
    }

    #[test]
    fn parses_paper_style_yaml() {
        let cfg = FedGraphConfig::parse_yaml(
            r#"
fedgraph_task: NC
dataset: cora-sim
method: FedGCN
global_rounds: 200
local_step: 3
learning_rate: 0.5
n_trainer: 10
num_hops: 1
iid_beta: 10000.0
use_encryption: true
he:
  poly_modulus_degree: 16384
  scale_bits: 40
network:
  bandwidth_gbps: 10.0
  latency_ms: 0.5
"#,
        )
        .unwrap();
        assert_eq!(cfg.method, Method::FedGcn);
        assert_eq!(cfg.global_rounds, 200);
        assert!(cfg.uses_he());
        assert_eq!(cfg.network.bandwidth_gbps, 10.0);
        if let PrivacyMode::He(p) = &cfg.privacy {
            assert_eq!(p.poly_mod_degree, 16384);
        }
    }

    #[test]
    fn parses_fault_tolerance_block_and_validates_windows() {
        let cfg = FedGraphConfig::parse_yaml(
            r#"
fedgraph_task: NC
dataset: cora-sim
method: FedAvg
federation:
  fault_tolerance:
    heartbeat_ms: 100
    worker_timeout_ms: 2000
    checkpoint_every: 5
    checkpoint_dir: /tmp/fg-ck
    reconnect_grace_ms: 750
    connect_retry_base_ms: 50
    connect_retry_cap_ms: 800
    connect_retry_budget_ms: 9000
"#,
        )
        .unwrap();
        assert_eq!(cfg.federation.fault_tolerance.heartbeat_ms, 100);
        assert_eq!(cfg.federation.fault_tolerance.worker_timeout_ms, 2000);
        assert_eq!(cfg.federation.fault_tolerance.checkpoint_every, 5);
        assert_eq!(cfg.federation.fault_tolerance.checkpoint_dir, "/tmp/fg-ck");
        assert_eq!(cfg.federation.fault_tolerance.reconnect_grace_ms, 750);
        assert_eq!(cfg.federation.fault_tolerance.connect_retry_base_ms, 50);
        assert_eq!(cfg.federation.fault_tolerance.connect_retry_cap_ms, 800);
        assert_eq!(cfg.federation.fault_tolerance.connect_retry_budget_ms, 9000);
        // Defaults: heartbeats on, 10 s liveness window, checkpoints off,
        // no durable store, no grace window, 100 ms → 2 s / 30 s backoff.
        let d = FaultToleranceConfig::default();
        assert_eq!(d.heartbeat_ms, 500);
        assert_eq!(d.worker_timeout_ms, 10_000);
        assert_eq!(d.checkpoint_every, 0);
        assert!(d.checkpoint_dir.is_empty());
        assert_eq!(d.reconnect_grace_ms, 0);
        assert_eq!(
            (d.connect_retry_base_ms, d.connect_retry_cap_ms, d.connect_retry_budget_ms),
            (100, 2_000, 30_000)
        );
        // A liveness window without heartbeats would kill idle live workers.
        let mut bad =
            FedGraphConfig::new(Task::NodeClassification, Method::FedAvgNC, "cora-sim").unwrap();
        bad.federation.fault_tolerance.heartbeat_ms = 0;
        assert!(bad.validate().is_err());
        // The window must cover at least two heartbeat intervals.
        bad.federation.fault_tolerance.heartbeat_ms = 800;
        bad.federation.fault_tolerance.worker_timeout_ms = 1000;
        assert!(bad.validate().is_err());
        // Disabling timeouts entirely is always valid.
        bad.federation.fault_tolerance.worker_timeout_ms = 0;
        bad.federation.fault_tolerance.heartbeat_ms = 0;
        bad.validate().unwrap();
        // A durable store with no checkpoint cadence would never persist.
        bad.federation.fault_tolerance.checkpoint_dir = "/tmp/fg-never".into();
        assert!(bad.validate().is_err());
        bad.federation.fault_tolerance.checkpoint_every = 1;
        bad.validate().unwrap();
        // Backoff schedule sanity: base >= 1, cap >= base.
        bad.federation.fault_tolerance.connect_retry_base_ms = 0;
        assert!(bad.validate().is_err());
        bad.federation.fault_tolerance.connect_retry_base_ms = 500;
        bad.federation.fault_tolerance.connect_retry_cap_ms = 100;
        assert!(bad.validate().is_err());
        bad.federation.fault_tolerance.connect_retry_cap_ms = 500;
        bad.validate().unwrap();
        // The block rides the bit-exact wire encoding.
        let mut wired =
            FedGraphConfig::new(Task::NodeClassification, Method::FedAvgNC, "cora-sim").unwrap();
        wired.federation.fault_tolerance = FaultToleranceConfig {
            heartbeat_ms: 250,
            worker_timeout_ms: 3000,
            checkpoint_every: 2,
            checkpoint_dir: "ckpts".into(),
            reconnect_grace_ms: 1200,
            connect_retry_base_ms: 25,
            connect_retry_cap_ms: 400,
            connect_retry_budget_ms: 5000,
        };
        let back = FedGraphConfig::decode_wire(&wired.encode_wire()).unwrap();
        assert_eq!(back.federation.fault_tolerance, wired.federation.fault_tolerance);
    }

    #[test]
    fn parses_federation_block() {
        let cfg = FedGraphConfig::parse_yaml(
            r#"
fedgraph_task: NC
dataset: cora-sim
method: FedAvg
federation:
  max_concurrency: 4
  dropout_frac: 0.25
  straggler_ms: 20.0
"#,
        )
        .unwrap();
        assert_eq!(cfg.federation.max_concurrency, 4);
        assert_eq!(cfg.federation.dropout_frac, 0.25);
        assert_eq!(cfg.federation.straggler_ms, 20.0);
        // Defaults when the block is absent.
        let plain =
            FedGraphConfig::new(Task::NodeClassification, Method::FedAvgNC, "cora-sim").unwrap();
        assert_eq!(plain.federation, FederationConfig::default());
        // Resolution: explicit cap wins, never above the participant count.
        assert_eq!(cfg.federation.resolved_concurrency(2), 2);
        assert_eq!(cfg.federation.resolved_concurrency(100), 4);
        assert!(FederationConfig::default().resolved_concurrency(100) >= 1);
        // Bad dropout rejected.
        assert!(FedGraphConfig::parse_yaml(
            "fedgraph_task: NC\ndataset: x\nmethod: FedAvg\nfederation:\n  dropout_frac: 1.0\n"
        )
        .is_err());
    }

    #[test]
    fn parses_async_mode_block() {
        let cfg = FedGraphConfig::parse_yaml(
            r#"
fedgraph_task: NC
dataset: cora-sim
method: FedAvg
federation:
  mode: async
  max_staleness: 3
  buffer_size: 5
  agg_shards: 4
"#,
        )
        .unwrap();
        assert_eq!(cfg.federation.mode, FederationMode::Async);
        assert_eq!(cfg.federation.max_staleness, 3);
        assert_eq!(cfg.federation.buffer_size, 5);
        assert_eq!(cfg.federation.agg_shards, 4);
        // Defaults: sync barrier, auto buffer/shards.
        let plain =
            FedGraphConfig::new(Task::NodeClassification, Method::FedAvgNC, "cora-sim").unwrap();
        assert_eq!(plain.federation.mode, FederationMode::Sync);
        assert_eq!(plain.federation.buffer_size, 0, "0 = auto (resolved by the policy)");
        // Unknown mode string rejected.
        assert!(FedGraphConfig::parse_yaml(
            "fedgraph_task: NC\ndataset: x\nmethod: FedAvg\nfederation:\n  mode: chaotic\n"
        )
        .is_err());
    }

    #[test]
    fn async_mode_validation_guards() {
        // Async + HE: staleness re-weighting cannot rescale ciphertexts.
        assert!(FedGraphConfig::parse_yaml(
            "fedgraph_task: NC\ndataset: x\nmethod: FedAvg\nuse_encryption: true\n\
             federation:\n  mode: async\n"
        )
        .is_err());
        // Async + GCFL: clustering is lockstep.
        assert!(FedGraphConfig::parse_yaml(
            "fedgraph_task: GC\ndataset: x\nmethod: GCFL\nfederation:\n  mode: async\n"
        )
        .is_err());
        // Async + SelfTrain: nothing to buffer.
        assert!(FedGraphConfig::parse_yaml(
            "fedgraph_task: GC\ndataset: x\nmethod: SelfTrain\nfederation:\n  mode: async\n"
        )
        .is_err());
        // Async + DP is fine.
        assert!(FedGraphConfig::parse_yaml(
            "fedgraph_task: NC\ndataset: x\nmethod: FedAvg\nuse_dp: true\n\
             federation:\n  mode: async\n"
        )
        .is_ok());
    }

    #[test]
    fn parses_compression_block_and_validates() {
        // Default is none.
        let plain =
            FedGraphConfig::new(Task::NodeClassification, Method::FedAvgNC, "cora-sim").unwrap();
        assert_eq!(plain.federation.compression, CompressionMode::None);
        // pack.
        let cfg = FedGraphConfig::parse_yaml(
            "fedgraph_task: NC\ndataset: x\nmethod: FedAvg\nfederation:\n  compression: pack\n",
        )
        .unwrap();
        assert_eq!(cfg.federation.compression, CompressionMode::Pack);
        // quantized with refinements.
        let cfg = FedGraphConfig::parse_yaml(
            "fedgraph_task: NC\ndataset: x\nmethod: FedAvg\nfederation:\n  compression: quantized\n  quantized_bits: 4\n  error_feedback: false\n",
        )
        .unwrap();
        assert_eq!(
            cfg.federation.compression,
            CompressionMode::Quantized { bits: 4, error_feedback: false }
        );
        // quantized defaults: int8 with error feedback.
        let cfg = FedGraphConfig::parse_yaml(
            "fedgraph_task: NC\ndataset: x\nmethod: FedAvg\nfederation:\n  compression: quantized\n",
        )
        .unwrap();
        assert_eq!(
            cfg.federation.compression,
            CompressionMode::Quantized { bits: 8, error_feedback: true }
        );
        // Unknown codec name rejected.
        assert!(FedGraphConfig::parse_yaml(
            "fedgraph_task: NC\ndataset: x\nmethod: FedAvg\nfederation:\n  compression: gzip\n"
        )
        .is_err());
        // Bad bit width rejected.
        assert!(FedGraphConfig::parse_yaml(
            "fedgraph_task: NC\ndataset: x\nmethod: FedAvg\nfederation:\n  compression: quantized\n  quantized_bits: 7\n"
        )
        .is_err());
        // quantized × HE rejected (quantization pairs with plaintext/DP only).
        assert!(FedGraphConfig::parse_yaml(
            "fedgraph_task: NC\ndataset: x\nmethod: FedAvg\nuse_encryption: true\nfederation:\n  compression: quantized\n"
        )
        .is_err());
        // pack × HE is allowed: the codec simply never sees a ciphertext
        // upload (HE payloads bypass the plaintext codec path).
        assert!(FedGraphConfig::parse_yaml(
            "fedgraph_task: NC\ndataset: x\nmethod: FedAvg\nuse_encryption: true\nfederation:\n  compression: pack\n"
        )
        .is_ok());
        // quantized × DP and quantized × async are fine.
        assert!(FedGraphConfig::parse_yaml(
            "fedgraph_task: NC\ndataset: x\nmethod: FedAvg\nuse_dp: true\nfederation:\n  mode: async\n  compression: quantized\n"
        )
        .is_ok());
        // Entropy defaults to none and parses next to pack.
        assert_eq!(plain.federation.entropy, EntropyMode::None);
        let cfg = FedGraphConfig::parse_yaml(
            "fedgraph_task: NC\ndataset: x\nmethod: FedAvg\nfederation:\n  compression: pack\n  entropy: rans\n",
        )
        .unwrap();
        assert_eq!(cfg.federation.entropy, EntropyMode::Rans);
        // rans is a stage behind pack: rejected with none/quantized.
        assert!(FedGraphConfig::parse_yaml(
            "fedgraph_task: NC\ndataset: x\nmethod: FedAvg\nfederation:\n  entropy: rans\n"
        )
        .is_err());
        assert!(FedGraphConfig::parse_yaml(
            "fedgraph_task: NC\ndataset: x\nmethod: FedAvg\nfederation:\n  compression: quantized\n  entropy: rans\n"
        )
        .is_err());
        // Unknown entropy coder rejected.
        assert!(FedGraphConfig::parse_yaml(
            "fedgraph_task: NC\ndataset: x\nmethod: FedAvg\nfederation:\n  compression: pack\n  entropy: huffman\n"
        )
        .is_err());
    }

    #[test]
    fn compression_modes_roundtrip_the_wire_codec() {
        let mut cfg =
            FedGraphConfig::new(Task::NodeClassification, Method::FedAvgNC, "cora-sim").unwrap();
        for mode in [
            CompressionMode::None,
            CompressionMode::Pack,
            CompressionMode::Quantized { bits: 4, error_feedback: false },
            CompressionMode::Quantized { bits: 8, error_feedback: true },
        ] {
            cfg.federation.compression = mode;
            let bytes = cfg.encode_wire();
            let back = FedGraphConfig::decode_wire(&bytes).unwrap();
            assert_eq!(back.federation.compression, mode);
            assert_eq!(back.encode_wire(), bytes);
        }
        // The entropy stage rides the wire next to the codec triple.
        cfg.federation.compression = CompressionMode::Pack;
        cfg.federation.entropy = EntropyMode::Rans;
        let bytes = cfg.encode_wire();
        let back = FedGraphConfig::decode_wire(&bytes).unwrap();
        assert_eq!(back.federation.entropy, EntropyMode::Rans);
        assert_eq!(back.encode_wire(), bytes);
    }

    #[test]
    fn rejects_invalid_configs() {
        assert!(FedGraphConfig::parse_yaml("dataset: x\nmethod: FedGCN\n").is_err()); // no task
        assert!(FedGraphConfig::parse_yaml(
            "fedgraph_task: NC\ndataset: x\nmethod: GCFL\n"
        )
        .is_err()); // wrong task-method
        assert!(FedGraphConfig::parse_yaml(
            "fedgraph_task: NC\ndataset: x\nmethod: FedGCN\nsample_ratio: 0.0\n"
        )
        .is_err());
        assert!(FedGraphConfig::parse_yaml(
            "fedgraph_task: NC\ndataset: x\nmethod: FedGCN\nuse_encryption: true\nuse_dp: true\n"
        )
        .is_err());
    }

    #[test]
    fn parses_transport_block_and_validates_tcp() {
        let cfg = FedGraphConfig::parse_yaml(
            r#"
fedgraph_task: NC
dataset: cora-sim
method: FedAvg
federation:
  transport: tcp
  listen_addr: 127.0.0.1:9911
  workers: 3
"#,
        )
        .unwrap();
        assert_eq!(cfg.federation.transport, TransportKind::Tcp);
        assert_eq!(cfg.federation.listen_addr, "127.0.0.1:9911");
        assert_eq!(cfg.federation.workers, 3);
        // Default stays in-process.
        let plain =
            FedGraphConfig::new(Task::NodeClassification, Method::FedAvgNC, "cora-sim").unwrap();
        assert_eq!(plain.federation.transport, TransportKind::Channel);
        // tcp with zero workers rejected.
        assert!(FedGraphConfig::parse_yaml(
            "fedgraph_task: NC\ndataset: x\nmethod: FedAvg\nfederation:\n  transport: tcp\n  workers: 0\n"
        )
        .is_err());
        // Unknown backend rejected.
        assert!(FedGraphConfig::parse_yaml(
            "fedgraph_task: NC\ndataset: x\nmethod: FedAvg\nfederation:\n  transport: carrier-pigeon\n"
        )
        .is_err());
    }

    #[test]
    fn config_wire_codec_roundtrips_every_field() {
        let mut cfg =
            FedGraphConfig::new(Task::GraphClassification, Method::FedProx, "mutag-sim").unwrap();
        cfg.n_trainer = 7;
        cfg.global_rounds = 31;
        cfg.local_steps = 5;
        cfg.learning_rate = 0.37;
        cfg.iid_beta = 0.1234567890123;
        cfg.sample_ratio = 0.75;
        cfg.sampling_type = SamplingType::Uniform;
        cfg.batch_size = 17;
        cfg.fedprox_mu = 0.0125;
        cfg.federation.transport = TransportKind::Tcp;
        cfg.federation.listen_addr = "127.0.0.1:0".into();
        cfg.federation.workers = 2;
        cfg.federation.max_concurrency = 3;
        cfg.federation.dropout_frac = 0.25;
        cfg.federation.straggler_ms = 12.5;
        cfg.federation.agg_shards = 4;
        cfg.network.bandwidth_gbps = 2.5;
        cfg.network.latency_ms = 0.125;
        cfg.seed = 0xDEAD_BEEF;
        cfg.scale = 0.333333333333;
        cfg.eval_every = 3;
        cfg.extras.insert("note".into(), "hello".into());
        let bytes = cfg.encode_wire();
        let back = FedGraphConfig::decode_wire(&bytes).unwrap();
        // Bit-exact roundtrip: re-encoding the decoded config reproduces the
        // same bytes (covers every field including the f64s).
        assert_eq!(back.encode_wire(), bytes);
        assert_eq!(back.method, Method::FedProx);
        assert_eq!(back.dataset, "mutag-sim");
        assert_eq!(back.federation.transport, TransportKind::Tcp);
        assert_eq!(back.seed, 0xDEAD_BEEF);
        assert_eq!(back.extras.get("note").map(|s| s.as_str()), Some("hello"));

        // Privacy variants roundtrip too.
        cfg.privacy = PrivacyMode::He(CkksParams::default_params());
        let he_bytes = cfg.encode_wire();
        let he_back = FedGraphConfig::decode_wire(&he_bytes).unwrap();
        assert_eq!(he_back.encode_wire(), he_bytes);
        assert!(he_back.uses_he());
        cfg.privacy = PrivacyMode::Dp(DpClone(DpParams::default_params()));
        let dp_bytes = cfg.encode_wire();
        assert_eq!(FedGraphConfig::decode_wire(&dp_bytes).unwrap().encode_wire(), dp_bytes);

        // Corruption is detected, never mis-parsed.
        let mut bad = bytes.clone();
        bad[10] ^= 0x08;
        assert!(FedGraphConfig::decode_wire(&bad).is_err());
    }

    #[test]
    fn dataset_format_parses_defaults_and_rides_the_wire() {
        // Default is v1 — the bitwise-pinned sequential generators — for
        // one release; v2 is opt-in.
        let plain =
            FedGraphConfig::new(Task::NodeClassification, Method::FedAvgNC, "cora-sim").unwrap();
        assert_eq!(plain.dataset_format, DatasetFormat::V1);
        let cfg = FedGraphConfig::parse_yaml(
            "fedgraph_task: NC\ndataset: cora-sim\nmethod: FedAvg\ndataset_format: v2\n",
        )
        .unwrap();
        assert_eq!(cfg.dataset_format, DatasetFormat::V2);
        // Unknown format rejected at parse time.
        assert!(FedGraphConfig::parse_yaml(
            "fedgraph_task: NC\ndataset: x\nmethod: FedAvg\ndataset_format: v3\n"
        )
        .is_err());
        // The knob rides the wire bit-exactly: a worker must generate the
        // same dataset format the coordinator did.
        for fmt in [DatasetFormat::V1, DatasetFormat::V2] {
            let mut cfg = plain.clone();
            cfg.dataset_format = fmt;
            let bytes = cfg.encode_wire();
            let back = FedGraphConfig::decode_wire(&bytes).unwrap();
            assert_eq!(back.dataset_format, fmt);
            assert_eq!(back.encode_wire(), bytes);
        }
        assert_eq!(DatasetFormat::parse("V2").unwrap(), DatasetFormat::V2);
        assert_eq!(DatasetFormat::V1.name(), "v1");
    }

    #[test]
    fn method_name_round_trip() {
        for (t, names) in [
            (Task::NodeClassification, vec!["FedAvg", "DistributedGCN", "BNS-GCN", "FedSage+", "FedGCN"]),
            (Task::GraphClassification, vec!["SelfTrain", "FedAvg", "FedProx", "GCFL", "GCFL+", "GCFL+dWs"]),
            (Task::LinkPrediction, vec!["StaticGNN", "STFL", "FedLink", "4D-FED-GNN+"]),
        ] {
            for n in names {
                let m = Method::parse(t, n).unwrap();
                assert_eq!(m.task(), t);
            }
        }
    }
}
