//! Report rendering: paper-style tables + JSON dumps.
//!
//! Two byte ledgers appear side by side: the **simulated** network
//! ([`crate::transport::SimNet`] — what the paper's link model charges) and
//! the **measured** wire ([`crate::transport::WireLedger`] — what the
//! transport backend actually moved, frame by frame). Cross-check invariant:
//! in uncompressed plaintext/DP sessions, measured *payload* wire bytes
//! equal the SimNet bytes exactly for payload frames (model broadcasts
//! charged at frame size and decoded uploads). The deliberate exceptions are
//! the round-0 bootstrap when charged `Free`, HE sessions (SimNet bills
//! ciphertext-size formulas while the stand-in broadcasts plaintext),
//! actor-staged simulated traffic (BNS-GCN halo re-shipments, FedLink
//! exchanges, the FedGCN pre-train exchange — simulated transfers with no
//! frame counterpart), control frames (measured, never charged), and
//! compressed transfers (`federation.compression: pack` keeps SimNet at the
//! *logical* plain-f32 size while the measured payload shrinks — uploads
//! *and* `SetModelPacked` broadcasts, further with `federation.entropy:
//! rans`). The wire table therefore prints measured payload bytes next to
//! logical bytes and their per-direction quotients — the **compression
//! ratios** (< 1.0 whenever the codec saved real bytes in that direction);
//! the same figures land in the JSON under each phase's `wire` entry plus
//! run-level `wire_compression_ratio` / `_up` / `_down` keys. The
//! per-direction split exists because a compressed uplink would otherwise
//! mask an uncompressed downlink (or vice versa) inside one blended number.

use crate::trace::{MetricsSnapshot, TrackSummary};
use crate::transport::{Direction, Phase, WireCounter};
use crate::util::json::{obj, Json};
use crate::util::tables::{fmt_bytes, fmt_secs, Table};

use super::Monitor;

/// A finished experiment summary extracted from a [`Monitor`].
pub struct Report {
    pub notes: Vec<(String, String)>,
    pub phase_secs: Vec<(String, f64)>,
    pub pretrain_bytes: u64,
    pub train_bytes: u64,
    pub pretrain_net_secs: f64,
    pub train_net_secs: f64,
    /// Concurrent-link simulated time (max over parallel links per
    /// collective) — the parallel federation's network wall clock.
    pub pretrain_net_concurrent_secs: f64,
    pub train_net_concurrent_secs: f64,
    /// Upload bytes the coordinator rejected as stale (async mode's
    /// staleness bound); a subset of the train-phase upload traffic.
    pub train_wasted_bytes: u64,
    pub final_accuracy: f64,
    pub final_loss: f64,
    pub total_rounds: usize,
    pub peak_rss: u64,
    pub rounds: Vec<super::RoundRecord>,
    /// Per-client totals `(client, compute, wait, transfer)` from the
    /// federation runtime's timelines (empty for non-federated runs).
    pub client_totals: Vec<(usize, f64, f64, f64)>,
    /// Transport backend name (`channel` / `tcp`) as noted by the runtime.
    pub transport: String,
    /// Session-build counters of **this** process: materialized clients and
    /// their approximate state bytes. The coordinator's full build counts
    /// every client; each worker's sliced counters arrive as
    /// `worker{k}_built_clients` / `worker{k}_session_bytes` notes (the
    /// per-worker startup/memory scaling axis).
    pub session_clients: usize,
    pub session_bytes: u64,
    /// Measured `startup` phase seconds (session build: datasets,
    /// partitions, pre-train exchanges, blocks, logic allocation).
    pub startup_secs: f64,
    /// Measured wire counters per `(phase, up, down)`: what the transport
    /// actually moved, next to the simulated ledger above (see module docs
    /// for the cross-check invariant).
    pub wire: Vec<(Phase, WireCounter, WireCounter)>,
    /// Collapsed per-track span totals of the merged flight-recorder
    /// timeline (empty unless the run was traced — `--trace` / `extras:
    /// trace: "1"`).
    pub trace_tracks: Vec<TrackSummary>,
    /// Trace events lost to recorder capacity bounds (coordinator + remote),
    /// surfaced so a truncated timeline is never mistaken for a complete one.
    pub trace_dropped: u64,
    /// Per-process resource snapshot series (`coord`, `worker0`, ...):
    /// workers stream these on update envelopes whether or not span tracing
    /// is on.
    pub worker_metrics: Vec<(String, Vec<MetricsSnapshot>)>,
    /// Worker-failure recoveries the coordinator performed (lane
    /// re-assignment after a `WorkerGone`); 0 for undisturbed runs.
    pub recoveries: u64,
    /// Client lanes moved to surviving workers across all recoveries.
    pub reassigned_clients: u64,
    /// Standby workers admitted at a round boundary after launch.
    pub late_joins: u64,
    /// Severed workers that re-handshook with their session token inside the
    /// reconnect grace window and reclaimed their slice without a recovery.
    pub reconnects: u64,
    /// Round checkpoints persisted to the durable store (0 without
    /// `fault_tolerance.checkpoint_dir`).
    pub checkpoint_writes: u64,
    /// Total bytes the durable checkpoint store committed.
    pub checkpoint_bytes: u64,
    /// Highest round with a durably persisted checkpoint, or `None` when no
    /// write happened — the round `--resume` would restart after.
    pub last_persisted_round: Option<u64>,
}

impl Report {
    pub fn from_monitor(m: &Monitor) -> Report {
        let pre = m.net.counter(Phase::PreTrain);
        let tr = m.net.counter(Phase::Train);
        let rounds = m.rounds();
        let (final_accuracy, final_loss) = rounds
            .last()
            .map(|r| (r.test_accuracy, r.train_loss))
            .unwrap_or((0.0, 0.0));
        let transport = m
            .notes()
            .iter()
            .rev()
            .find(|(k, _)| k == "transport")
            .map(|(_, v)| v.clone())
            .unwrap_or_default();
        let wire: Vec<(Phase, WireCounter, WireCounter)> =
            [Phase::PreTrain, Phase::Train, Phase::Eval]
                .into_iter()
                .map(|p| {
                    (p, m.wire.counter(p, Direction::Up), m.wire.counter(p, Direction::Down))
                })
                .filter(|(_, up, down)| up.frames + down.frames > 0)
                .collect();
        let (session_clients, session_bytes) = m.session_build();
        let note_u64 = |key: &str| {
            m.notes()
                .iter()
                .rev()
                .find(|(k, _)| k == key)
                .and_then(|(_, v)| v.parse().ok())
                .unwrap_or(0u64)
        };
        Report {
            notes: m.notes(),
            startup_secs: m.phase_secs("startup"),
            session_clients,
            session_bytes,
            phase_secs: m.phase_names().iter().map(|p| (p.clone(), m.phase_secs(p))).collect(),
            pretrain_bytes: pre.bytes_up + pre.bytes_down,
            train_bytes: tr.bytes_up + tr.bytes_down,
            pretrain_net_secs: pre.sim_secs,
            train_net_secs: tr.sim_secs,
            pretrain_net_concurrent_secs: pre.concurrent_secs,
            train_net_concurrent_secs: tr.concurrent_secs,
            train_wasted_bytes: tr.wasted_bytes,
            final_accuracy,
            final_loss,
            total_rounds: rounds.len(),
            peak_rss: m.peak_rss(),
            rounds,
            client_totals: m.timeline_totals(),
            transport,
            wire,
            trace_tracks: m.trace_summary(),
            trace_dropped: m.flight.dropped(),
            worker_metrics: m.process_samples(),
            recoveries: note_u64("recoveries"),
            reassigned_clients: note_u64("reassigned_clients"),
            late_joins: note_u64("late_joins"),
            reconnects: note_u64("reconnects"),
            checkpoint_writes: note_u64("checkpoint_writes"),
            checkpoint_bytes: note_u64("checkpoint_bytes"),
            last_persisted_round: m
                .notes()
                .iter()
                .rev()
                .find(|(k, _)| k == "last_persisted_round")
                .and_then(|(_, v)| v.parse().ok()),
        }
    }

    /// Total measured wire bytes (both directions, all phases).
    pub fn wire_bytes(&self) -> u64 {
        self.wire.iter().map(|(_, up, down)| up.bytes + down.bytes).sum()
    }

    /// Total measured payload bytes (what the transport actually moved for
    /// data-plane frames — compressed when an upload codec is active).
    pub fn wire_payload_bytes(&self) -> u64 {
        self.wire.iter().map(|(_, up, down)| up.payload_bytes + down.payload_bytes).sum()
    }

    /// Total logical (uncompressed-equivalent) payload bytes.
    pub fn wire_logical_bytes(&self) -> u64 {
        self.wire.iter().map(|(_, up, down)| up.logical_bytes + down.logical_bytes).sum()
    }

    /// Measured payload bytes over logical payload bytes across all phases
    /// and both directions: 1.0 without compression, < 1.0 when a codec
    /// saved real wire bytes. The blended headline number — see the
    /// per-direction [`Report::wire_compression_ratio_up`] /
    /// [`Report::wire_compression_ratio_down`] for the honest split.
    pub fn wire_compression_ratio(&self) -> f64 {
        let logical = self.wire_logical_bytes();
        if logical == 0 {
            1.0
        } else {
            self.wire_payload_bytes() as f64 / logical as f64
        }
    }

    fn ratio_of(payload: u64, logical: u64) -> f64 {
        if logical == 0 {
            1.0
        } else {
            payload as f64 / logical as f64
        }
    }

    /// Uplink (client → coordinator) measured/logical payload ratio across
    /// all phases: what the `pack`/`quantized` upload codec saved.
    pub fn wire_compression_ratio_up(&self) -> f64 {
        let payload: u64 = self.wire.iter().map(|(_, up, _)| up.payload_bytes).sum();
        let logical: u64 = self.wire.iter().map(|(_, up, _)| up.logical_bytes).sum();
        Self::ratio_of(payload, logical)
    }

    /// Downlink (coordinator → client) measured/logical payload ratio across
    /// all phases: what the `SetModelPacked` broadcast codec saved.
    pub fn wire_compression_ratio_down(&self) -> f64 {
        let payload: u64 = self.wire.iter().map(|(_, _, down)| down.payload_bytes).sum();
        let logical: u64 = self.wire.iter().map(|(_, _, down)| down.logical_bytes).sum();
        Self::ratio_of(payload, logical)
    }

    pub fn total_bytes(&self) -> u64 {
        self.pretrain_bytes + self.train_bytes
    }

    /// Total measured compute seconds (sum over "pretrain"/"train"/
    /// "aggregate"/"eval" phases only — HE sub-phases are included in these).
    pub fn compute_secs(&self) -> f64 {
        self.phase_secs
            .iter()
            .filter(|(p, _)| matches!(p.as_str(), "pretrain" | "train" | "aggregate" | "eval"))
            .map(|(_, s)| s)
            .sum()
    }

    /// Render the human-readable report (the library's stdout summary).
    pub fn render(&self) -> String {
        let mut out = String::new();
        if !self.notes.is_empty() {
            out.push_str("run: ");
            let parts: Vec<String> =
                self.notes.iter().map(|(k, v)| format!("{k}={v}")).collect();
            out.push_str(&parts.join(" "));
            out.push('\n');
        }
        let mut t = Table::new(&["phase", "measured s"]).with_title("Time by phase");
        for (p, s) in &self.phase_secs {
            t.row(&[p.clone(), fmt_secs(*s)]);
        }
        out.push_str(&t.render());
        let mut c = Table::new(&["phase", "bytes", "serial net s", "concurrent net s"])
            .with_title("Communication cost");
        c.row(&[
            "pre-train".into(),
            fmt_bytes(self.pretrain_bytes),
            fmt_secs(self.pretrain_net_secs),
            fmt_secs(self.pretrain_net_concurrent_secs),
        ]);
        c.row(&[
            "train".into(),
            fmt_bytes(self.train_bytes),
            fmt_secs(self.train_net_secs),
            fmt_secs(self.train_net_concurrent_secs),
        ]);
        c.row(&[
            "total".into(),
            fmt_bytes(self.total_bytes()),
            fmt_secs(self.pretrain_net_secs + self.train_net_secs),
            fmt_secs(self.pretrain_net_concurrent_secs + self.train_net_concurrent_secs),
        ]);
        out.push_str(&c.render());
        if !self.wire.is_empty() {
            let title = if self.transport.is_empty() {
                "Wire (measured)".to_string()
            } else {
                format!("Wire (measured, transport={})", self.transport)
            };
            let mut w = Table::new(&[
                "phase",
                "frames",
                "bytes",
                "payload bytes",
                "logical bytes",
                "ratio up",
                "ratio down",
            ])
            .with_title(&title);
            let fmt_ratio = |payload: u64, logical: u64| {
                if logical == 0 {
                    "-".to_string()
                } else {
                    format!("{:.2}", payload as f64 / logical as f64)
                }
            };
            for (phase, up, down) in &self.wire {
                let payload = up.payload_bytes + down.payload_bytes;
                let logical = up.logical_bytes + down.logical_bytes;
                w.row(&[
                    phase.name().into(),
                    (up.frames + down.frames).to_string(),
                    fmt_bytes(up.bytes + down.bytes),
                    fmt_bytes(payload),
                    fmt_bytes(logical),
                    fmt_ratio(up.payload_bytes, up.logical_bytes),
                    fmt_ratio(down.payload_bytes, down.logical_bytes),
                ]);
            }
            out.push_str(&w.render());
        }
        if self.train_wasted_bytes > 0 {
            out.push_str(&format!(
                "stale-rejected upload waste: {} (async staleness bound)\n",
                fmt_bytes(self.train_wasted_bytes)
            ));
        }
        if self.recoveries > 0 || self.late_joins > 0 || self.reconnects > 0 {
            out.push_str(&format!(
                "fault tolerance: {} recoveries, {} clients re-assigned, {} late joins, \
                 {} reconnects\n",
                self.recoveries, self.reassigned_clients, self.late_joins, self.reconnects
            ));
        }
        if self.checkpoint_writes > 0 {
            out.push_str(&format!(
                "durable checkpoints: {} written ({}), last persisted round {}\n",
                self.checkpoint_writes,
                fmt_bytes(self.checkpoint_bytes),
                self.last_persisted_round
                    .map(|r| r.to_string())
                    .unwrap_or_else(|| "-".into())
            ));
        }
        if self.session_clients > 0 {
            out.push_str(&format!(
                "session build: {} clients materialized, {} state ({} startup)\n",
                self.session_clients,
                fmt_bytes(self.session_bytes),
                fmt_secs(self.startup_secs)
            ));
        }
        if !self.trace_tracks.is_empty() {
            let mut t = Table::new(&["track", "spans", "busy s", "instants"])
                .with_title("Trace (flight recorder)");
            for s in &self.trace_tracks {
                t.row(&[
                    s.track.clone(),
                    s.spans.to_string(),
                    fmt_secs(s.busy_secs),
                    s.instants.to_string(),
                ]);
            }
            out.push_str(&t.render());
            if self.trace_dropped > 0 {
                out.push_str(&format!(
                    "trace events dropped (recorder capacity): {}\n",
                    self.trace_dropped
                ));
            }
        }
        if !self.worker_metrics.is_empty() {
            let mut t =
                Table::new(&["process", "samples", "peak rss", "cpu s", "max queue"])
                    .with_title("Process metrics (streamed)");
            for (label, samples) in &self.worker_metrics {
                let peak = samples.iter().map(|s| s.rss_bytes).max().unwrap_or(0);
                let cpu = samples.iter().map(|s| s.cpu_seconds).fold(0.0f64, f64::max);
                let queue = samples.iter().map(|s| s.queue_depth).max().unwrap_or(0);
                t.row(&[
                    label.clone(),
                    samples.len().to_string(),
                    fmt_bytes(peak),
                    fmt_secs(cpu),
                    queue.to_string(),
                ]);
            }
            out.push_str(&t.render());
        }
        if !self.client_totals.is_empty() {
            let mut t = Table::new(&["client", "compute s", "wait s", "transfer s"])
                .with_title("Per-client timeline");
            for (client, compute, wait, transfer) in &self.client_totals {
                t.row(&[
                    client.to_string(),
                    fmt_secs(*compute),
                    fmt_secs(*wait),
                    fmt_secs(*transfer),
                ]);
            }
            out.push_str(&t.render());
        }
        out.push_str(&format!(
            "rounds={} final_loss={:.4} final_accuracy={:.4} peak_rss={}\n",
            self.total_rounds,
            self.final_loss,
            self.final_accuracy,
            fmt_bytes(self.peak_rss)
        ));
        out
    }

    /// Machine-readable dump (one JSON document per run; benches aggregate
    /// these into the paper's figures).
    pub fn to_json(&self) -> Json {
        let notes = Json::Obj(
            self.notes.iter().map(|(k, v)| (k.clone(), Json::Str(v.clone()))).collect(),
        );
        let phases = Json::Obj(
            self.phase_secs.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect(),
        );
        let rounds = Json::Arr(
            self.rounds
                .iter()
                .map(|r| {
                    obj(vec![
                        ("round", r.round.into()),
                        ("train_secs", r.train_secs.into()),
                        ("agg_secs", r.agg_secs.into()),
                        ("sim_net_secs", r.sim_net_secs.into()),
                        ("train_loss", r.train_loss.into()),
                        ("test_accuracy", r.test_accuracy.into()),
                    ])
                })
                .collect(),
        );
        let clients = Json::Arr(
            self.client_totals
                .iter()
                .map(|(client, compute, wait, transfer)| {
                    obj(vec![
                        ("client", (*client).into()),
                        ("compute_secs", (*compute).into()),
                        ("wait_secs", (*wait).into()),
                        ("transfer_secs", (*transfer).into()),
                    ])
                })
                .collect(),
        );
        let wire = Json::Obj(
            self.wire
                .iter()
                .map(|(phase, up, down)| {
                    (
                        phase.name().to_string(),
                        obj(vec![
                            ("frames", ((up.frames + down.frames) as usize).into()),
                            ("bytes_up", (up.bytes as usize).into()),
                            ("bytes_down", (down.bytes as usize).into()),
                            ("payload_bytes_up", (up.payload_bytes as usize).into()),
                            ("payload_bytes_down", (down.payload_bytes as usize).into()),
                            ("logical_bytes_up", (up.logical_bytes as usize).into()),
                            ("logical_bytes_down", (down.logical_bytes as usize).into()),
                        ]),
                    )
                })
                .collect(),
        );
        // Observability sections: always present (empty when untraced /
        // single-process) so consumers can rely on the document shape.
        let trace_tracks = Json::Arr(
            self.trace_tracks
                .iter()
                .map(|s| {
                    obj(vec![
                        ("track", Json::Str(s.track.clone())),
                        ("spans", (s.spans as usize).into()),
                        ("busy_secs", s.busy_secs.into()),
                        ("instants", (s.instants as usize).into()),
                    ])
                })
                .collect(),
        );
        let worker_metrics = Json::Obj(
            self.worker_metrics
                .iter()
                .map(|(label, samples)| {
                    (
                        label.clone(),
                        Json::Arr(
                            samples
                                .iter()
                                .map(|s| {
                                    obj(vec![
                                        ("at_ns", (s.at_ns as usize).into()),
                                        ("rss_bytes", (s.rss_bytes as usize).into()),
                                        ("cpu_seconds", s.cpu_seconds.into()),
                                        ("queue_depth", (s.queue_depth as usize).into()),
                                    ])
                                })
                                .collect(),
                        ),
                    )
                })
                .collect(),
        );
        obj(vec![
            ("notes", notes),
            ("phase_secs", phases),
            ("transport", Json::Str(self.transport.clone())),
            ("wire", wire),
            ("trace_tracks", trace_tracks),
            ("trace_dropped", (self.trace_dropped as usize).into()),
            ("worker_metrics", worker_metrics),
            ("wire_compression_ratio", self.wire_compression_ratio().into()),
            ("wire_compression_ratio_up", self.wire_compression_ratio_up().into()),
            ("wire_compression_ratio_down", self.wire_compression_ratio_down().into()),
            ("startup_secs", self.startup_secs.into()),
            ("session_clients", self.session_clients.into()),
            ("session_bytes", (self.session_bytes as usize).into()),
            ("pretrain_bytes", (self.pretrain_bytes as usize).into()),
            ("train_bytes", (self.train_bytes as usize).into()),
            ("pretrain_net_secs", self.pretrain_net_secs.into()),
            ("train_net_secs", self.train_net_secs.into()),
            ("pretrain_net_concurrent_secs", self.pretrain_net_concurrent_secs.into()),
            ("train_net_concurrent_secs", self.train_net_concurrent_secs.into()),
            ("train_wasted_bytes", (self.train_wasted_bytes as usize).into()),
            ("final_accuracy", self.final_accuracy.into()),
            ("final_loss", self.final_loss.into()),
            ("peak_rss", (self.peak_rss as usize).into()),
            // Fault-tolerance outcome: always present (zeros for undisturbed
            // runs) so ci.sh validators can rely on the section's shape.
            (
                "recovery",
                obj(vec![
                    ("recoveries", (self.recoveries as usize).into()),
                    ("reassigned_clients", (self.reassigned_clients as usize).into()),
                    ("late_joins", (self.late_joins as usize).into()),
                    ("reconnects", (self.reconnects as usize).into()),
                    ("checkpoint_writes", (self.checkpoint_writes as usize).into()),
                    ("checkpoint_bytes", (self.checkpoint_bytes as usize).into()),
                    (
                        "last_persisted_round",
                        match self.last_persisted_round {
                            Some(r) => (r as usize).into(),
                            None => Json::Null,
                        },
                    ),
                ]),
            ),
            ("rounds", rounds),
            ("clients", clients),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::monitor::RoundRecord;
    use crate::transport::{Direction, NetConfig, SimNet};
    use std::sync::Arc;

    #[test]
    fn report_extraction_and_rendering() {
        let m = Monitor::new(Arc::new(SimNet::new(NetConfig::default())));
        m.note("dataset", "cora-sim");
        m.add_secs("train", 1.5);
        m.add_secs("pretrain", 0.5);
        m.net.send(Phase::PreTrain, Direction::Up, 2_000_000);
        m.net.send(Phase::Train, Direction::Down, 1_000_000);
        m.record_round(RoundRecord {
            round: 0,
            train_secs: 1.5,
            agg_secs: 0.1,
            sim_net_secs: 0.02,
            train_loss: 0.7,
            test_accuracy: 0.81,
        });
        m.record_timeline(crate::monitor::ClientTimeline {
            round: 0,
            client: 0,
            compute_secs: 1.5,
            wait_secs: 0.1,
            transfer_secs: 0.02,
        });
        m.sample_resources();
        m.note("transport", "channel");
        m.wire.record_payload_frame(Phase::Train, Direction::Down, 1_000_000);
        m.wire.record_frame(Phase::Train, Direction::Up, 50);
        m.add_secs("startup", 0.125);
        m.count_built_client(4096);
        m.count_built_client(4096);
        let r = Report::from_monitor(&m);
        assert_eq!(r.pretrain_bytes, 2_000_000);
        assert_eq!(r.train_bytes, 1_000_000);
        assert_eq!(r.final_accuracy, 0.81);
        assert_eq!(r.transport, "channel");
        assert_eq!(r.wire_bytes(), 1_000_050);
        assert_eq!(r.wire.len(), 1, "only phases with frames are listed");
        // Singles: concurrent == serial.
        assert!((r.train_net_concurrent_secs - r.train_net_secs).abs() < 1e-12);
        assert_eq!(r.client_totals.len(), 1);
        assert!((r.compute_secs() - 2.0).abs() < 1e-9);
        assert_eq!(r.session_clients, 2);
        assert_eq!(r.session_bytes, 8192);
        assert!((r.startup_secs - 0.125).abs() < 1e-12);
        let text = r.render();
        assert!(text.contains("cora-sim"));
        assert!(text.contains("2.00 MB"));
        assert!(text.contains("transport=channel"), "wire table names the backend:\n{text}");
        assert!(text.contains("session build: 2 clients"), "build counters render:\n{text}");
        // JSON parses back
        let j = r.to_json();
        let parsed = crate::util::json::Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(parsed.get("final_accuracy").as_f64(), Some(0.81));
        assert_eq!(parsed.get("rounds").as_arr().unwrap().len(), 1);
        assert_eq!(parsed.get("transport").as_str(), Some("channel"));
        assert_eq!(parsed.get("session_clients").as_f64(), Some(2.0));
        assert_eq!(parsed.get("session_bytes").as_f64(), Some(8192.0));
        assert_eq!(parsed.get("startup_secs").as_f64(), Some(0.125));
        let wire_train = parsed.get("wire").get("train");
        assert_eq!(wire_train.get("payload_bytes_down").as_f64(), Some(1_000_000.0));
        assert_eq!(wire_train.get("logical_bytes_down").as_f64(), Some(1_000_000.0));
        assert_eq!(wire_train.get("bytes_up").as_f64(), Some(50.0));
        // No codec in play: measured payload == logical payload, ratio 1.0
        // in every direction.
        assert!((r.wire_compression_ratio() - 1.0).abs() < 1e-12);
        assert!((r.wire_compression_ratio_up() - 1.0).abs() < 1e-12);
        assert!((r.wire_compression_ratio_down() - 1.0).abs() < 1e-12);
        assert_eq!(parsed.get("wire_compression_ratio").as_f64(), Some(1.0));
        assert_eq!(parsed.get("wire_compression_ratio_up").as_f64(), Some(1.0));
        assert_eq!(parsed.get("wire_compression_ratio_down").as_f64(), Some(1.0));
    }

    #[test]
    fn report_json_schema_is_stable() {
        // The golden-schema gate: every consumer-visible top-level key is
        // always present — observability sections included, even for an
        // untraced single-process run — so downstream tooling (benches,
        // ci.sh validators) can rely on the document shape.
        let m = Monitor::new(Arc::new(SimNet::new(NetConfig::default())));
        m.note("dataset", "cora-sim");
        m.wire.record_frame(Phase::Train, Direction::Up, 50);
        let r = Report::from_monitor(&m);
        let json = r.to_json();
        let keys: Vec<&str> = match &json {
            Json::Obj(fields) => fields.iter().map(|(k, _)| k.as_str()).collect(),
            other => panic!("report JSON must be an object, got {other:?}"),
        };
        // `Json::Obj` is a BTreeMap, so keys iterate alphabetically.
        assert_eq!(
            keys,
            vec![
                "clients",
                "final_accuracy",
                "final_loss",
                "notes",
                "peak_rss",
                "phase_secs",
                "pretrain_bytes",
                "pretrain_net_concurrent_secs",
                "pretrain_net_secs",
                "recovery",
                "rounds",
                "session_bytes",
                "session_clients",
                "startup_secs",
                "trace_dropped",
                "trace_tracks",
                "train_bytes",
                "train_net_concurrent_secs",
                "train_net_secs",
                "train_wasted_bytes",
                "transport",
                "wire",
                "wire_compression_ratio",
                "wire_compression_ratio_down",
                "wire_compression_ratio_up",
            ],
            "top-level report schema drifted"
        );
        let parsed = Json::parse(&json.to_string_pretty()).unwrap();
        assert_eq!(
            parsed.get("trace_tracks").as_arr().map(|a| a.len()),
            Some(0),
            "untraced runs carry an empty trace_tracks array"
        );
        assert_eq!(parsed.get("trace_dropped").as_f64(), Some(0.0));
        match parsed.get("worker_metrics") {
            Json::Obj(v) => assert!(v.is_empty(), "no processes streamed metrics"),
            other => panic!("worker_metrics must be an object, got {other:?}"),
        }
        // Undisturbed runs still carry the recovery section, zeroed — the
        // durable-orchestration keys included (null last round: no write).
        let rec = parsed.get("recovery");
        assert_eq!(rec.get("recoveries").as_f64(), Some(0.0));
        assert_eq!(rec.get("reassigned_clients").as_f64(), Some(0.0));
        assert_eq!(rec.get("late_joins").as_f64(), Some(0.0));
        assert_eq!(rec.get("reconnects").as_f64(), Some(0.0));
        assert_eq!(rec.get("checkpoint_writes").as_f64(), Some(0.0));
        assert_eq!(rec.get("checkpoint_bytes").as_f64(), Some(0.0));
        assert_eq!(rec.get("last_persisted_round"), &Json::Null);
        let rec_keys: Vec<&str> = match rec {
            Json::Obj(fields) => fields.iter().map(|(k, _)| k.as_str()).collect(),
            other => panic!("recovery must be an object, got {other:?}"),
        };
        assert_eq!(
            rec_keys,
            vec![
                "checkpoint_bytes",
                "checkpoint_writes",
                "last_persisted_round",
                "late_joins",
                "reassigned_clients",
                "reconnects",
                "recoveries",
            ],
            "recovery section schema drifted"
        );

        // Traced/multi-process shape: one absorbed obs block fills both
        // sections with their fixed per-entry keys.
        m.absorb_remote_obs(
            "worker0",
            0,
            vec![crate::trace::TraceEvent {
                track: "client1".into(),
                name: "compute".into(),
                kind: crate::trace::EventKind::Span,
                start_ns: 1_000,
                dur_ns: 500,
                args: vec![],
            }],
            Some(MetricsSnapshot {
                at_ns: 2_000,
                rss_bytes: 1 << 20,
                cpu_seconds: 0.25,
                queue_depth: 3,
            }),
            2,
        );
        let parsed =
            Json::parse(&Report::from_monitor(&m).to_json().to_string_pretty()).unwrap();
        let tracks = parsed.get("trace_tracks").as_arr().unwrap();
        assert_eq!(tracks.len(), 1);
        assert_eq!(tracks[0].get("track").as_str(), Some("worker0/client1"));
        assert_eq!(tracks[0].get("spans").as_f64(), Some(1.0));
        assert!(tracks[0].get("busy_secs").as_f64().unwrap() > 0.0);
        assert_eq!(tracks[0].get("instants").as_f64(), Some(0.0));
        assert_eq!(parsed.get("trace_dropped").as_f64(), Some(2.0));
        let samples = parsed.get("worker_metrics").get("worker0").as_arr().unwrap();
        assert_eq!(samples.len(), 1);
        assert_eq!(samples[0].get("rss_bytes").as_f64(), Some((1 << 20) as f64));
        assert_eq!(samples[0].get("cpu_seconds").as_f64(), Some(0.25));
        assert_eq!(samples[0].get("queue_depth").as_f64(), Some(3.0));
        assert!(samples[0].get("at_ns").as_f64().is_some());
        let text = Report::from_monitor(&m).render();
        assert!(text.contains("Trace (flight recorder)"), "trace table renders:\n{text}");
        assert!(text.contains("Process metrics"), "metrics table renders:\n{text}");
        assert!(text.contains("trace events dropped"), "drop note renders:\n{text}");
    }

    #[test]
    fn recovery_notes_fill_the_recovery_section() {
        let m = Monitor::new(Arc::new(SimNet::new(NetConfig::default())));
        m.note("recoveries", 1u64);
        m.note("reassigned_clients", 3u64);
        m.note("late_joins", 1u64);
        m.note("reconnects", 2u64);
        m.note("checkpoint_writes", 5u64);
        m.note("checkpoint_bytes", 40_960u64);
        m.note("last_persisted_round", 9u64);
        let r = Report::from_monitor(&m);
        assert_eq!((r.recoveries, r.reassigned_clients, r.late_joins), (1, 3, 1));
        assert_eq!((r.reconnects, r.checkpoint_writes, r.checkpoint_bytes), (2, 5, 40_960));
        assert_eq!(r.last_persisted_round, Some(9));
        let text = r.render();
        assert!(
            text.contains(
                "fault tolerance: 1 recoveries, 3 clients re-assigned, 1 late joins, \
                 2 reconnects"
            ),
            "recovery line renders:\n{text}"
        );
        assert!(
            text.contains("durable checkpoints: 5 written"),
            "checkpoint line renders:\n{text}"
        );
        assert!(text.contains("last persisted round 9"), "persisted round renders:\n{text}");
        let j = Json::parse(&r.to_json().to_string_pretty()).unwrap();
        assert_eq!(j.get("recovery").get("reassigned_clients").as_f64(), Some(3.0));
        assert_eq!(j.get("recovery").get("reconnects").as_f64(), Some(2.0));
        assert_eq!(j.get("recovery").get("checkpoint_writes").as_f64(), Some(5.0));
        assert_eq!(j.get("recovery").get("checkpoint_bytes").as_f64(), Some(40_960.0));
        assert_eq!(j.get("recovery").get("last_persisted_round").as_f64(), Some(9.0));
    }

    #[test]
    fn compressed_payloads_show_a_sub_one_ratio() {
        let m = Monitor::new(Arc::new(SimNet::new(NetConfig::default())));
        m.note("transport", "channel");
        m.note("compression", "pack");
        // A packed upload: 1 MB logical shipped as 300 kB on the wire.
        m.wire.record_frame(Phase::Train, Direction::Up, 300_060);
        m.wire.note_payload(Phase::Train, Direction::Up, 300_000, 1_000_000);
        // A packed broadcast: 2 MB logical shipped as 1 MB on the wire.
        m.wire.record_frame(Phase::Train, Direction::Down, 1_000_020);
        m.wire.note_payload(Phase::Train, Direction::Down, 1_000_000, 2_000_000);
        let r = Report::from_monitor(&m);
        assert_eq!(r.wire_payload_bytes(), 1_300_000);
        assert_eq!(r.wire_logical_bytes(), 3_000_000);
        // Per-direction ratios, not a blended number: 0.3 up, 0.5 down.
        assert!((r.wire_compression_ratio_up() - 0.3).abs() < 1e-12);
        assert!((r.wire_compression_ratio_down() - 0.5).abs() < 1e-12);
        assert!((r.wire_compression_ratio() - 1_300_000.0 / 3_000_000.0).abs() < 1e-12);
        let text = r.render();
        assert!(text.contains("ratio up"), "per-direction columns must render:\n{text}");
        assert!(text.contains("0.30"), "uplink ratio must render:\n{text}");
        assert!(text.contains("0.50"), "downlink ratio must render:\n{text}");
        let j = crate::util::json::Json::parse(&r.to_json().to_string_pretty()).unwrap();
        let ratio = j.get("wire_compression_ratio").as_f64().unwrap();
        assert!(ratio < 1.0, "JSON must expose the sub-1.0 ratio, got {ratio}");
        assert_eq!(j.get("wire_compression_ratio_up").as_f64(), Some(0.3));
        assert_eq!(j.get("wire_compression_ratio_down").as_f64(), Some(0.5));
    }
}
