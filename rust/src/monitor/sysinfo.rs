//! Process resource sampling (the Grafana/Prometheus stand-in).
//!
//! Reads Linux `/proc` for RSS memory and CPU time, giving the monitor the
//! "CPU / memory usage over time" data of the paper's Fig 11 dashboard.

use std::time::Instant;

/// Current resident set size in bytes (0 if unavailable).
///
/// Primary source is the `VmRSS:` line of `/proc/self/status`, which the
/// kernel reports in kB regardless of the page size — correct on 16k/64k-page
/// kernels (arm64 servers, ppc64) where a hardcoded 4096-byte page would
/// under-report RSS by 4–16x. `/proc/self/statm` (reported in pages) is kept
/// as a fallback, scaled by an assumed 4096-byte page.
pub fn rss_bytes() -> u64 {
    if let Ok(s) = std::fs::read_to_string("/proc/self/status") {
        for line in s.lines() {
            if let Some(rest) = line.strip_prefix("VmRSS:") {
                if let Some(kb) = rest.split_whitespace().next() {
                    if let Ok(kb) = kb.parse::<u64>() {
                        return kb * 1024;
                    }
                }
            }
        }
    }
    // /proc/self/statm: size resident shared text lib data dt (pages)
    if let Ok(s) = std::fs::read_to_string("/proc/self/statm") {
        if let Some(resident) = s.split_whitespace().nth(1) {
            if let Ok(pages) = resident.parse::<u64>() {
                return pages * fallback_page_size();
            }
        }
    }
    0
}

fn fallback_page_size() -> u64 {
    // Only reached when /proc/self/status has no VmRSS line; statm reports
    // pages, and without a syscall we can only assume the x86-64/aarch64
    // default. The VmRSS path above is page-size-independent.
    4096
}

/// Cumulative user+system CPU seconds of this process.
///
/// `utime`/`stime` in `/proc/<pid>/stat` are expressed in `USER_HZ` ticks.
/// `USER_HZ` is a kernel *ABI* constant fixed at 100 on every mainstream
/// Linux architecture (distinct from the kernel's internal `CONFIG_HZ`,
/// which may be 250/1000) — the same constant `ps`/`top` assume — so we
/// divide by 100 rather than shelling out to `getconf CLK_TCK`.
pub fn cpu_seconds() -> f64 {
    if let Ok(s) = std::fs::read_to_string("/proc/self/stat") {
        // Fields 14 and 15 (utime, stime) in clock ticks, after the comm
        // field which may contain spaces — find the closing paren first.
        if let Some(close) = s.rfind(')') {
            let rest: Vec<&str> = s[close + 1..].split_whitespace().collect();
            if rest.len() > 13 {
                let utime: f64 = rest[11].parse().unwrap_or(0.0);
                let stime: f64 = rest[12].parse().unwrap_or(0.0);
                let hz = 100.0; // USER_HZ (see above)
                return (utime + stime) / hz;
            }
        }
    }
    0.0
}

/// A resource sample tagged with elapsed wall-clock time.
#[derive(Clone, Debug)]
pub struct ResourceSample {
    pub elapsed_secs: f64,
    pub rss_bytes: u64,
    pub cpu_seconds: f64,
}

/// Samples resources relative to a start instant.
pub struct ResourceProbe {
    start: Instant,
    cpu0: f64,
}

impl ResourceProbe {
    pub fn new() -> ResourceProbe {
        ResourceProbe { start: Instant::now(), cpu0: cpu_seconds() }
    }

    pub fn sample(&self) -> ResourceSample {
        ResourceSample {
            elapsed_secs: self.start.elapsed().as_secs_f64(),
            rss_bytes: rss_bytes(),
            cpu_seconds: cpu_seconds() - self.cpu0,
        }
    }
}

impl Default for ResourceProbe {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rss_is_positive_on_linux() {
        assert!(rss_bytes() > 1_000_000, "rss should be at least 1 MB");
    }

    #[test]
    fn cpu_seconds_monotone() {
        let a = cpu_seconds();
        // burn a little CPU
        let mut x = 0u64;
        for i in 0..3_000_000u64 {
            x = x.wrapping_add(i * i);
        }
        std::hint::black_box(x);
        let b = cpu_seconds();
        assert!(b >= a);
    }

    #[test]
    fn probe_samples() {
        let p = ResourceProbe::new();
        let s = p.sample();
        assert!(s.elapsed_secs >= 0.0);
        assert!(s.rss_bytes > 0);
    }
}
