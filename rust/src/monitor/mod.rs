//! The FedGraph Monitoring System (paper §3.1).
//!
//! A `Monitor` instance accompanies every experiment run and records:
//! - wall-clock **time per phase** (pre-train communication, local training,
//!   aggregation, evaluation) via resumable stopwatches plus externally
//!   measured chunks reported by trainer threads;
//! - **communication cost** by phase and direction (delegated to
//!   [`crate::transport::SimNet`], which it holds);
//! - per-round **training curves** (loss, accuracy, time) — Fig 11 left;
//! - periodic **CPU / memory samples** — Fig 11 right;
//! and renders the paper-style report tables plus a machine-readable JSON
//! document (see [`report`]).

pub mod report;
pub mod sysinfo;

use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Mutex};

use crate::trace::{self, FlightRecorder, MetricsSnapshot, TraceEvent, TrackSummary};
use crate::transport::{Phase, SimNet, WireLedger};
use crate::util::json::Json;
use crate::util::timer::Stopwatch;

use sysinfo::{ResourceProbe, ResourceSample};

/// Per-round record (one point of the Fig 11 accuracy curves).
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub round: usize,
    /// Measured local-training seconds (max over participating clients —
    /// the round's critical path, as in the paper's synchronous setting).
    pub train_secs: f64,
    /// Measured aggregation seconds at the server.
    pub agg_secs: f64,
    /// Simulated network seconds for the round, concurrent-link model (max
    /// over parallel links per collective, not the serial sum).
    pub sim_net_secs: f64,
    pub train_loss: f64,
    pub test_accuracy: f64,
}

/// One client's share of one round, split the way the paper's per-pod
/// telemetry splits it: compute (local training, incl. injected straggle),
/// wait (blocked on the concurrency gate / barrier), and simulated transfer
/// time of its own up/down payloads.
#[derive(Clone, Debug)]
pub struct ClientTimeline {
    pub round: usize,
    pub client: usize,
    pub compute_secs: f64,
    pub wait_secs: f64,
    pub transfer_secs: f64,
}

struct MonitorState {
    stopwatches: BTreeMap<String, Stopwatch>,
    /// Externally measured seconds per phase (from trainer threads).
    extras: HashMap<String, f64>,
    rounds: Vec<RoundRecord>,
    samples: Vec<ResourceSample>,
    peak_rss: u64,
    notes: Vec<(String, String)>,
    timelines: Vec<ClientTimeline>,
    /// Session-build counters: how many clients this process materialized
    /// and their approximate state bytes (the sliced-build scaling axis).
    session_clients: usize,
    session_bytes: u64,
    /// Per-process [`MetricsSnapshot`] series on the coordinator's trace
    /// clock: this process under "coord", plus every worker's envelope-borne
    /// samples (rebased by its handshake clock offset) under "worker{k}".
    process_samples: BTreeMap<String, Vec<MetricsSnapshot>>,
}

/// The monitor class (thread-safe; trainers and the server share it).
pub struct Monitor {
    pub net: Arc<SimNet>,
    /// Measured wire bytes: what the transport backend actually moved,
    /// frame by frame, recorded by the coordinator's event loop. Lives next
    /// to the simulated [`SimNet`] ledger so the report can cross-check the
    /// two (`wire payload bytes == SimNet bytes` for charged payload frames
    /// in plaintext/DP sessions).
    pub wire: WireLedger,
    /// The run's flight recorder (see [`crate::trace`]): the merge target
    /// for every thread's span buffer and every worker's envelope-borne
    /// trace events. **Not** auto-installed — the coordinator entry point
    /// installs it for the run when `cfg.trace_enabled()` (span recording
    /// stays off otherwise, and probes cost one relaxed atomic load).
    pub flight: Arc<FlightRecorder>,
    state: Mutex<MonitorState>,
    probe: ResourceProbe,
}

impl Monitor {
    pub fn new(net: Arc<SimNet>) -> Monitor {
        Monitor {
            net,
            wire: WireLedger::new(),
            flight: FlightRecorder::new("coord"),
            state: Mutex::new(MonitorState {
                stopwatches: BTreeMap::new(),
                extras: HashMap::new(),
                rounds: Vec::new(),
                samples: Vec::new(),
                peak_rss: 0,
                notes: Vec::new(),
                timelines: Vec::new(),
                session_clients: 0,
                session_bytes: 0,
                process_samples: BTreeMap::new(),
            }),
            probe: ResourceProbe::new(),
        }
    }

    /// Start the named phase stopwatch ("pretrain", "train", "aggregate",
    /// "eval", "he_encrypt", ...). A `start` while the phase is already
    /// running is an instrumentation bug and is ledgered as a
    /// `monitor_misuse` report note (the stopwatch itself is unharmed —
    /// `Stopwatch::start` is idempotent).
    pub fn start(&self, phase: &str) {
        let mut st = self.state.lock().unwrap();
        let sw = st.stopwatches.entry(phase.to_string()).or_default();
        if sw.is_running() {
            let note = format!("duplicate start('{phase}')");
            st.notes.push(("monitor_misuse".to_string(), note));
        } else {
            sw.start();
        }
    }

    /// Stop the named phase stopwatch. A `stop` with no running span (never
    /// started, or already stopped) is an instrumentation bug and is
    /// ledgered as a `monitor_misuse` report note instead of silently
    /// no-op'ing.
    pub fn stop(&self, phase: &str) {
        let mut st = self.state.lock().unwrap();
        match st.stopwatches.get_mut(phase) {
            Some(sw) if sw.is_running() => sw.stop(),
            _ => {
                let note = format!("stop('{phase}') without a running start");
                st.notes.push(("monitor_misuse".to_string(), note));
            }
        }
    }

    /// Add seconds measured externally (e.g. inside a trainer thread).
    pub fn add_secs(&self, phase: &str, secs: f64) {
        let mut st = self.state.lock().unwrap();
        *st.extras.entry(phase.to_string()).or_insert(0.0) += secs;
    }

    /// Measured seconds for a phase (stopwatch + external chunks).
    pub fn phase_secs(&self, phase: &str) -> f64 {
        let st = self.state.lock().unwrap();
        let sw = st.stopwatches.get(phase).map(|s| s.secs()).unwrap_or(0.0);
        sw + st.extras.get(phase).copied().unwrap_or(0.0)
    }

    /// Record a completed round.
    pub fn record_round(&self, rec: RoundRecord) {
        self.state.lock().unwrap().rounds.push(rec);
    }

    pub fn rounds(&self) -> Vec<RoundRecord> {
        self.state.lock().unwrap().rounds.clone()
    }

    /// Take a CPU/memory sample (the paper's Prometheus scrape equivalent).
    /// Also appends a trace-clock [`MetricsSnapshot`] to this process's
    /// `"coord"` series, so the merged timeline's counter tracks cover the
    /// coordinator next to the workers.
    pub fn sample_resources(&self) {
        let s = self.probe.sample();
        let snap = MetricsSnapshot {
            at_ns: trace::now_ns(),
            rss_bytes: s.rss_bytes,
            cpu_seconds: sysinfo::cpu_seconds(),
            queue_depth: 0,
        };
        let mut st = self.state.lock().unwrap();
        st.peak_rss = st.peak_rss.max(s.rss_bytes);
        st.samples.push(s);
        st.process_samples.entry("coord".to_string()).or_default().push(snap);
    }

    pub fn samples(&self) -> Vec<ResourceSample> {
        self.state.lock().unwrap().samples.clone()
    }

    pub fn peak_rss(&self) -> u64 {
        self.state.lock().unwrap().peak_rss
    }

    /// Attach a free-form note to the report ("dataset=cora-sim", ...).
    pub fn note(&self, key: &str, value: impl std::fmt::Display) {
        let mut st = self.state.lock().unwrap();
        st.notes.push((key.to_string(), value.to_string()));
    }

    pub fn notes(&self) -> Vec<(String, String)> {
        self.state.lock().unwrap().notes.clone()
    }

    /// Count one materialized client of this process's session build
    /// (`bytes` ≈ its per-client state: feature tables, local adjacency,
    /// padded blocks). Task builders call this once per client their
    /// [`crate::coordinator::BuildSlice`] materializes, so a sliced worker
    /// build's counters cover exactly its assigned clients.
    pub fn count_built_client(&self, bytes: u64) {
        let mut st = self.state.lock().unwrap();
        st.session_clients += 1;
        st.session_bytes += bytes;
    }

    /// `(materialized clients, approximate session-state bytes)` of this
    /// process's session build — what a worker reports in its `BuildReport`
    /// and the report surfaces next to the `startup` phase timing.
    pub fn session_build(&self) -> (usize, u64) {
        let st = self.state.lock().unwrap();
        (st.session_clients, st.session_bytes)
    }

    /// Record one client's round timeline (from the federation runtime).
    pub fn record_timeline(&self, t: ClientTimeline) {
        self.state.lock().unwrap().timelines.push(t);
    }

    pub fn timelines(&self) -> Vec<ClientTimeline> {
        self.state.lock().unwrap().timelines.clone()
    }

    /// Per-client totals over all rounds: `(client, compute, wait, transfer)`
    /// seconds, sorted by client index.
    pub fn timeline_totals(&self) -> Vec<(usize, f64, f64, f64)> {
        let st = self.state.lock().unwrap();
        let mut by_client: BTreeMap<usize, (f64, f64, f64)> = BTreeMap::new();
        for t in &st.timelines {
            let e = by_client.entry(t.client).or_insert((0.0, 0.0, 0.0));
            e.0 += t.compute_secs;
            e.1 += t.wait_secs;
            e.2 += t.transfer_secs;
        }
        by_client.into_iter().map(|(c, (a, b, d))| (c, a, b, d)).collect()
    }

    /// Simulated network seconds for a phase (serialized single-wire model).
    pub fn net_secs(&self, phase: Phase) -> f64 {
        self.net.counter(phase).sim_secs
    }

    /// Simulated network seconds for a phase under the concurrent-link model
    /// (grouped transfers contribute their slowest link only).
    pub fn net_concurrent_secs(&self, phase: Phase) -> f64 {
        self.net.counter(phase).concurrent_secs
    }

    /// Merge a remote process's observation block into the unified timeline:
    /// trace events and the optional resource snapshot are rebased from the
    /// worker's trace clock onto ours (`offset_ns` = worker-minus-coord,
    /// estimated at the `WorkerHello → Assign` handshake), event tracks get
    /// a `{label}/` prefix so the export maps them to their own process, and
    /// remote buffer drops are carried into this recorder's drop count.
    /// Pure observation: nothing here touches either communication ledger.
    pub fn absorb_remote_obs(
        &self,
        label: &str,
        offset_ns: i64,
        events: Vec<TraceEvent>,
        snapshot: Option<MetricsSnapshot>,
        dropped: u64,
    ) {
        let rebase = |t: u64| -> u64 { (t as i128 - offset_ns as i128).max(0) as u64 };
        if !events.is_empty() {
            let events = events
                .into_iter()
                .map(|mut ev| {
                    ev.start_ns = rebase(ev.start_ns);
                    if !label.is_empty() {
                        ev.track = format!("{label}/{}", ev.track);
                    }
                    ev
                })
                .collect();
            self.flight.absorb(events);
        }
        self.flight.add_dropped(dropped);
        if let Some(mut snap) = snapshot {
            snap.at_ns = rebase(snap.at_ns);
            let key = if label.is_empty() { "coord".to_string() } else { label.to_string() };
            self.state.lock().unwrap().process_samples.entry(key).or_default().push(snap);
        }
    }

    /// Collapsed per-track span totals of the merged timeline (the report's
    /// trace table).
    pub fn trace_summary(&self) -> Vec<TrackSummary> {
        trace::summarize(&self.flight.snapshot_events())
    }

    /// Per-process [`MetricsSnapshot`] series (coordinator + workers),
    /// sorted by process label.
    pub fn process_samples(&self) -> Vec<(String, Vec<MetricsSnapshot>)> {
        self.state.lock().unwrap().process_samples.clone().into_iter().collect()
    }

    /// The merged timeline as Chrome trace-event JSON (Perfetto /
    /// `chrome://tracing` loadable) — what `--trace <path>` writes.
    pub fn chrome_trace(&self) -> Json {
        trace::chrome_trace_json(&self.flight.snapshot_events(), &self.process_samples())
    }

    /// All phase names with any recorded time, sorted.
    pub fn phase_names(&self) -> Vec<String> {
        let st = self.state.lock().unwrap();
        let mut names: Vec<String> = st.stopwatches.keys().cloned().collect();
        for k in st.extras.keys() {
            if !names.contains(k) {
                names.push(k.clone());
            }
        }
        names.sort();
        names
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::{Direction, NetConfig};

    fn monitor() -> Monitor {
        Monitor::new(Arc::new(SimNet::new(NetConfig::default())))
    }

    #[test]
    fn stopwatch_phases() {
        let m = monitor();
        m.start("train");
        std::thread::sleep(std::time::Duration::from_millis(3));
        m.stop("train");
        assert!(m.phase_secs("train") > 0.0);
        assert_eq!(m.phase_secs("eval"), 0.0);
    }

    #[test]
    fn external_secs_accumulate() {
        let m = monitor();
        m.add_secs("he_encrypt", 0.5);
        m.add_secs("he_encrypt", 0.25);
        assert!((m.phase_secs("he_encrypt") - 0.75).abs() < 1e-12);
        assert!(m.phase_names().contains(&"he_encrypt".to_string()));
    }

    #[test]
    fn rounds_and_samples() {
        let m = monitor();
        m.record_round(RoundRecord {
            round: 0,
            train_secs: 0.1,
            agg_secs: 0.01,
            sim_net_secs: 0.02,
            train_loss: 1.9,
            test_accuracy: 0.3,
        });
        m.sample_resources();
        assert_eq!(m.rounds().len(), 1);
        assert_eq!(m.samples().len(), 1);
        assert!(m.peak_rss() > 0);
    }

    #[test]
    fn timelines_aggregate_per_client() {
        let m = monitor();
        for round in 0..3 {
            for client in 0..2 {
                m.record_timeline(ClientTimeline {
                    round,
                    client,
                    compute_secs: 1.0,
                    wait_secs: 0.5,
                    transfer_secs: 0.25,
                });
            }
        }
        assert_eq!(m.timelines().len(), 6);
        let totals = m.timeline_totals();
        assert_eq!(totals.len(), 2);
        for (client, compute, wait, transfer) in totals {
            assert!(client < 2);
            assert!((compute - 3.0).abs() < 1e-12);
            assert!((wait - 1.5).abs() < 1e-12);
            assert!((transfer - 0.75).abs() < 1e-12);
        }
    }

    #[test]
    fn session_build_counters_accumulate() {
        let m = monitor();
        assert_eq!(m.session_build(), (0, 0));
        m.count_built_client(1000);
        m.count_built_client(24);
        assert_eq!(m.session_build(), (2, 1024));
    }

    #[test]
    fn misuse_start_stop_is_ledgered() {
        let m = monitor();
        m.stop("never-started");
        m.start("train");
        m.start("train"); // duplicate while running
        m.stop("train");
        m.stop("train"); // stop after stop
        let notes = m.notes();
        let misuse: Vec<&String> =
            notes.iter().filter(|(k, _)| k == "monitor_misuse").map(|(_, v)| v).collect();
        assert_eq!(misuse.len(), 3, "all three misuses ledger a note: {notes:?}");
        assert!(misuse[0].contains("never-started"));
        assert!(misuse[1].contains("duplicate start"));
        // The stopwatch itself stays coherent through the misuse.
        assert!(m.phase_secs("train") >= 0.0);
    }

    #[test]
    fn remote_obs_merges_with_prefix_and_offset() {
        let m = monitor();
        let ev = TraceEvent {
            track: "client1".into(),
            name: "compute".into(),
            kind: crate::trace::EventKind::Span,
            start_ns: 5_000,
            dur_ns: 100,
            args: vec![],
        };
        let snap =
            MetricsSnapshot { at_ns: 6_000, rss_bytes: 1, cpu_seconds: 0.5, queue_depth: 2 };
        m.absorb_remote_obs("worker0", 1_000, vec![ev], Some(snap), 4);
        let evs = m.flight.snapshot_events();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].track, "worker0/client1", "worker tracks get a process prefix");
        assert_eq!(evs[0].start_ns, 4_000, "worker clock rebased by the offset");
        assert_eq!(m.flight.dropped(), 4, "remote drops carry over");
        let ps = m.process_samples();
        assert_eq!(ps.len(), 1);
        assert_eq!(ps[0].0, "worker0");
        assert_eq!(ps[0].1[0].at_ns, 5_000);
        let sum = m.trace_summary();
        assert_eq!(sum[0].track, "worker0/client1");
        assert!(m.chrome_trace().to_string().contains("worker0"));
    }

    #[test]
    fn net_integration() {
        let m = monitor();
        m.net.send(Phase::PreTrain, Direction::Up, 1_000_000);
        assert!(m.net_secs(Phase::PreTrain) > 0.0);
        assert_eq!(m.net.counter(Phase::PreTrain).bytes_up, 1_000_000);
    }
}
