//! # fedgraph
//!
//! A reproduction of *"FedGraph: A Research Library and Benchmark for
//! Federated Graph Learning"* (Yao et al., 2024) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! - **Layer 3 (this crate)** — the federated coordinator: server/trainer
//!   topology over a simulated network, plain / homomorphic-encrypted /
//!   differentially-private aggregation, the low-rank pre-train
//!   communication scheme, client selection, minibatch scheduling, and the
//!   monitoring system that regenerates every figure and table of the
//!   paper's evaluation.
//!
//! # Federation architecture
//!
//! Since the actor-runtime refactor, trainers are no longer iterated by a
//! sequential loop: each client is an **actor on its own OS thread** with an
//! mpsc mailbox, and the coordinator drives a typed round protocol
//! (`Rendezvous → BroadcastModel → LocalTrain → UploadUpdate → Aggregate →
//! next round | Finish`) over a pluggable byte transport. Where the actors
//! *live* is a [`federation::Deployment`]: threads in this process
//! (`federation.transport: channel`, the default) or separate
//! `fedgraph worker` processes over sockets (`federation.transport: tcp` —
//! loopback runs are bitwise-identical to in-process runs). Worker
//! processes rebuild **only their assigned slice** of the session
//! ([`coordinator::build_session_sliced`] with the `Assign` slice plan), so
//! per-machine startup cost and memory are O(assigned clients) while the
//! materialized slice stays bitwise-identical to a full build's — see
//! `docs/DEPLOYMENT.md`. See
//! [`federation`] for the protocol and determinism contract,
//! [`transport::link`] / [`transport::tcp`] for the frame movers,
//! [`transport::serialize`] for the wire format and the pluggable upload
//! codecs (`federation.compression: none | pack | quantized` — `pack` is
//! lossless and bitwise-transparent, `quantized` trades accuracy for
//! bytes), and the `federation:` config block (`max_concurrency`,
//! `dropout_frac`, `straggler_ms`, `transport`, `listen_addr`, `workers`,
//! `compression`) for runtime knobs — `docs/CONFIG.md` is the full key
//! reference. Parallel execution is bitwise-identical to
//! `max_concurrency: 1`; per-client compute/wait/transfer timelines,
//! measured wire bytes, and the compression ratio land in the monitor's
//! report.
//! - **Layer 2 (python/compile/model.py, build-time only)** — GCN / GIN / LP
//!   models and their train/eval steps in JAX, AOT-lowered to HLO text.
//! - **Layer 1 (python/compile/kernels/, build-time only)** — Pallas kernels
//!   for the dense compute hot-spots, validated against pure-jnp oracles.
//!
//! At runtime the Rust binary loads `artifacts/*.hlo.txt` through the PJRT
//! CPU client (`runtime::Engine`) and never touches Python.
//!
//! Quickstart (the paper's Fig 2 experience):
//!
//! ```no_run
//! use fedgraph::config::FedGraphConfig;
//! let cfg = FedGraphConfig::parse_yaml(r#"
//! fedgraph_task: NC
//! dataset: cora-sim
//! method: FedGCN
//! n_trainer: 10
//! global_rounds: 50
//! "#).unwrap();
//! let report = fedgraph::run_fedgraph(&cfg).unwrap();
//! println!("{}", report.render());
//! ```

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod federation;
pub mod graph;
pub mod he;
pub mod lowrank;
pub mod monitor;
pub mod runtime;
pub mod testing;
pub mod trace;
pub mod transport;
pub mod util;

pub use config::FedGraphConfig;
pub use coordinator::run_fedgraph;
pub use monitor::report::Report;
