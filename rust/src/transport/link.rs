//! Pluggable federation transport: byte-frame links between the coordinator
//! and its trainer endpoints.
//!
//! The layering mirrors a real deployment stack (full wire reference:
//! `docs/WIRE_FORMAT.md`):
//!
//! - **`federation::protocol`** turns typed round-protocol messages into
//!   checksummed byte frames (via [`super::serialize`], whose upload codecs
//!   — negotiated during the `WorkerHello → Assign` handshake on
//!   multi-process backends — may compress the update payloads inside those
//!   frames; links move opaque frames and never care);
//! - **this module** defines the endpoint traits a backend implements —
//!   [`CoordLink`] (coordinator side) and [`TrainerLink`] (trainer side) —
//!   plus backend #1; backend selection lives in
//!   `crate::federation::deploy::Deployment`;
//! - **[`super::SimNet`]** is the ledger: the federation runtime charges each
//!   payload frame to it by phase/direction so communication cost stays exact
//!   regardless of backend.
//!
//! Backend #1 is [`ChannelTransport`]: per-trainer mpsc channels, the
//! in-process equivalent of the paper's Ray/gRPC links between EKS pods.
//! Backend #2 lives in [`super::tcp`]: multiplexed socket lanes to separate
//! `fedgraph worker` processes. Both produce the same boxed [`CoordLink`] /
//! [`TrainerLink`] endpoints, so everything above this layer is identical.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

/// A serialized protocol message moving across a link. Reference-counted so
/// a broadcast to 1000 trainers shares one encoded buffer instead of copying
/// it per link (`Vec<u8>` payloads convert with `.into()`).
pub type Frame = Arc<[u8]>;

/// Coordinator side of the fabric: one outgoing lane per trainer, one shared
/// incoming lane (frames are tagged with the sender's client index).
pub trait CoordLink: Send {
    /// Queue a frame for trainer `client`.
    fn send(&mut self, client: usize, frame: Frame) -> Result<()>;
    /// Block until the next frame from any trainer arrives.
    fn recv(&mut self) -> Result<(usize, Frame)>;
    /// Non-blocking poll: `Ok(None)` when no frame is waiting. The async
    /// round policy drains already-arrived straggler updates with this
    /// before issuing new train orders.
    fn try_recv(&mut self) -> Result<Option<(usize, Frame)>>;

    // --- Elastic-membership extensions (protocol v6) -----------------------
    //
    // Default implementations refuse: only multi-connection backends
    // (`super::tcp::TcpCoord`) support worker-level control traffic, lane
    // migration, and late admission. The in-process channel backend hosts
    // every trainer in one process, so there is no worker to lose or admit —
    // the federation runtime only calls these after a typed
    // `super::tcp::WorkerGone` or a late-join rendezvous, which only TCP
    // deployments produce.

    /// Send a control frame to worker connection `conn` (not to a trainer
    /// lane) — carries `Reassign` orders during recovery.
    fn send_control(&mut self, _conn: usize, _frame: Frame) -> Result<()> {
        bail!("control-lane sends unsupported by this transport")
    }

    /// Re-route these trainer lanes to worker connection `conn` (after the
    /// receiving worker registered them — see the recovery sequence in
    /// `docs/FAULT_TOLERANCE.md`).
    fn reroute(&mut self, _clients: &[usize], _conn: usize) -> Result<()> {
        bail!("lane rerouting unsupported by this transport")
    }

    /// Admit a handshaken late worker connection; returns its connection
    /// index for subsequent `send_control`/`reroute` calls.
    fn add_conn(&mut self, _stream: std::net::TcpStream) -> Result<usize> {
        bail!("late connections unsupported by this transport")
    }
}

/// Trainer side of the fabric: a duplex lane to the coordinator.
pub trait TrainerLink: Send {
    fn send(&mut self, frame: Frame) -> Result<()>;
    /// Block until the next coordinator frame arrives.
    fn recv(&mut self) -> Result<Frame>;
}

// ---------------------------------------------------------------------------
// In-memory mpsc backend
// ---------------------------------------------------------------------------

/// In-memory channel transport (first backend): trainer actors live on OS
/// threads in this process and frames move through `std::sync::mpsc`.
pub struct ChannelTransport;

struct ChannelCoord {
    downs: Vec<Sender<Frame>>,
    up: Receiver<(usize, Frame)>,
}

struct ChannelTrainer {
    client: usize,
    down: Receiver<Frame>,
    up: Sender<(usize, Frame)>,
}

impl CoordLink for ChannelCoord {
    fn send(&mut self, client: usize, frame: Frame) -> Result<()> {
        self.downs
            .get(client)
            .ok_or_else(|| anyhow!("no such trainer {client}"))?
            .send(frame)
            .map_err(|_| anyhow!("trainer {client} hung up"))
    }

    fn recv(&mut self) -> Result<(usize, Frame)> {
        self.up.recv().map_err(|_| anyhow!("all trainers hung up"))
    }

    fn try_recv(&mut self) -> Result<Option<(usize, Frame)>> {
        use std::sync::mpsc::TryRecvError;
        match self.up.try_recv() {
            Ok(x) => Ok(Some(x)),
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => Err(anyhow!("all trainers hung up")),
        }
    }
}

impl TrainerLink for ChannelTrainer {
    fn send(&mut self, frame: Frame) -> Result<()> {
        self.up.send((self.client, frame)).map_err(|_| anyhow!("coordinator hung up"))
    }

    fn recv(&mut self) -> Result<Frame> {
        self.down.recv().map_err(|_| anyhow!("coordinator hung up"))
    }
}

impl ChannelTransport {
    /// Open the coordinator endpoint plus `n` in-process trainer endpoints.
    /// Like every backend, preserves per-lane FIFO order; delivery across
    /// different trainers may interleave arbitrarily.
    pub fn open(&self, n: usize) -> Result<(Box<dyn CoordLink>, Vec<Box<dyn TrainerLink>>)> {
        let (up_tx, up_rx) = channel::<(usize, Frame)>();
        let mut downs = Vec::with_capacity(n);
        let mut trainers: Vec<Box<dyn TrainerLink>> = Vec::with_capacity(n);
        for client in 0..n {
            let (down_tx, down_rx) = channel::<Frame>();
            downs.push(down_tx);
            trainers.push(Box::new(ChannelTrainer {
                client,
                down: down_rx,
                up: up_tx.clone(),
            }));
        }
        Ok((Box::new(ChannelCoord { downs, up: up_rx }), trainers))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(bytes: &[u8]) -> Frame {
        bytes.to_vec().into()
    }

    #[test]
    fn frames_roundtrip_both_directions() {
        let (mut coord, mut trainers) = ChannelTransport.open(3).unwrap();
        coord.send(1, frame(&[0xAB, 0xCD])).unwrap();
        let mut t1 = trainers.remove(1);
        assert_eq!(&*t1.recv().unwrap(), &[0xAB, 0xCD]);
        t1.send(frame(&[7])).unwrap();
        let (from, f) = coord.recv().unwrap();
        assert_eq!(from, 1);
        assert_eq!(&*f, &[7]);
    }

    #[test]
    fn per_lane_fifo() {
        let (mut coord, mut trainers) = ChannelTransport.open(1).unwrap();
        coord.send(0, frame(&[1])).unwrap();
        coord.send(0, frame(&[2])).unwrap();
        let t = &mut trainers[0];
        assert_eq!(&*t.recv().unwrap(), &[1]);
        assert_eq!(&*t.recv().unwrap(), &[2]);
    }

    #[test]
    fn try_recv_polls_without_blocking() {
        let (mut coord, mut trainers) = ChannelTransport.open(2).unwrap();
        assert!(coord.try_recv().unwrap().is_none(), "empty fabric must not block");
        trainers[1].send(frame(&[9])).unwrap();
        let (from, f) = coord.try_recv().unwrap().expect("frame was queued");
        assert_eq!(from, 1);
        assert_eq!(&*f, &[9]);
        assert!(coord.try_recv().unwrap().is_none());
    }

    #[test]
    fn bad_client_errors() {
        let (mut coord, _trainers) = ChannelTransport.open(2).unwrap();
        assert!(coord.send(5, frame(&[])).is_err());
    }

    #[test]
    fn works_across_threads() {
        let (mut coord, trainers) = ChannelTransport.open(4).unwrap();
        let mut handles = Vec::new();
        for mut t in trainers {
            handles.push(std::thread::spawn(move || {
                let f = t.recv().unwrap();
                t.send(f).unwrap(); // echo
            }));
        }
        for c in 0..4 {
            coord.send(c, frame(&[c as u8])).unwrap();
        }
        let mut seen = std::collections::HashSet::new();
        for _ in 0..4 {
            let (from, f) = coord.recv().unwrap();
            assert_eq!(&*f, &[from as u8]);
            seen.insert(from);
        }
        assert_eq!(seen.len(), 4);
        for h in handles {
            h.join().unwrap();
        }
    }
}
