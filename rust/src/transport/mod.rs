//! Simulated federation network + measured wire accounting.
//!
//! The paper deploys trainers on AWS EKS pods and measures bytes + transfer
//! time between them. Two ledgers live here:
//!
//! - [`SimNet`] — the *simulated* network: every logical transfer passes
//!   through [`SimNet::send`], which (a) counts the serialized bytes by phase
//!   and direction, and (b) converts bytes to *simulated* wall-clock seconds
//!   with a bandwidth + latency link model. Measured (CPU) time and simulated
//!   (network) time are reported separately by the monitor so both the
//!   "training time" and "communication cost" axes of Figs 5–10 can be
//!   regenerated.
//! - [`WireLedger`] — the *measured* wire: the federation runtime counts the
//!   actual byte length of every protocol frame it ships or receives, by
//!   phase and direction, and separately tracks how many of those bytes are
//!   data-plane payload (the portion SimNet charges). For uncompressed
//!   plaintext/DP sessions the invariant `wire payload bytes == SimNet
//!   bytes` holds exactly for payload frames (model broadcasts + uploads) —
//!   the report prints both so the simulated ledger can be cross-checked
//!   against what the transport really moved. The two diverge only where
//!   they should: HE sessions bill ciphertext sizes while this
//!   implementation's decrypted stand-in broadcasts plaintext frames,
//!   actor-staged *simulated* transfers (BNS-GCN halo re-shipments, FedLink
//!   per-step exchanges, the FedGCN pre-train exchange) have no frame
//!   counterpart at all, and under `federation.compression: pack` the
//!   measured payload shrinks below the SimNet charge in *both* directions
//!   — uploads and `SetModelPacked` broadcasts — while the SimNet charge
//!   stays at the logical plain-f32 size so `pack` is ledger-transparent
//!   (`federation.entropy: rans` shrinks the measured side further, same
//!   contract). Each [`WireCounter`] therefore carries both a measured
//!   `payload_bytes` and a `logical_bytes` figure; their quotient is the
//!   per-direction compression ratio the report prints. The full
//!   framing/codec byte layout lives in `docs/WIRE_FORMAT.md`.
//!
//! Since the deployment refactor trainers may also live in separate worker
//! processes over the [`tcp`] backend; the byte ledger stays coordinator-side
//! (remote actors report their staged in-round traffic inside their update
//! envelopes — see [`SimNet::take_staged`]).

pub mod link;
pub mod serialize;
pub mod tcp;

use std::collections::HashMap;
use std::sync::Mutex;

/// Which phase of the pipeline a transfer belongs to (the paper splits
/// communication into pre-train and train; Figs 5/7/9 stack these).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Phase {
    PreTrain,
    Train,
    Eval,
}

impl Phase {
    pub fn name(&self) -> &'static str {
        match self {
            Phase::PreTrain => "pretrain",
            Phase::Train => "train",
            Phase::Eval => "eval",
        }
    }

    /// Stable wire code (staged transfers ride update envelopes in
    /// multi-process mode).
    pub fn code(&self) -> u8 {
        match self {
            Phase::PreTrain => 0,
            Phase::Train => 1,
            Phase::Eval => 2,
        }
    }

    pub fn from_code(c: u8) -> Option<Phase> {
        match c {
            0 => Some(Phase::PreTrain),
            1 => Some(Phase::Train),
            2 => Some(Phase::Eval),
            _ => None,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Direction {
    /// Client → server.
    Up,
    /// Server → client(s).
    Down,
}

impl Direction {
    pub fn code(&self) -> u8 {
        match self {
            Direction::Up => 0,
            Direction::Down => 1,
        }
    }

    pub fn from_code(c: u8) -> Option<Direction> {
        match c {
            0 => Some(Direction::Up),
            1 => Some(Direction::Down),
            _ => None,
        }
    }
}

/// Link model.
#[derive(Clone, Debug)]
pub struct NetConfig {
    pub bandwidth_gbps: f64,
    pub latency_ms: f64,
}

impl Default for NetConfig {
    fn default() -> Self {
        // Same-region cloud instances (the paper's EKS testbed).
        NetConfig { bandwidth_gbps: 1.0, latency_ms: 1.0 }
    }
}

#[derive(Clone, Debug, Default)]
pub struct PhaseCounter {
    pub bytes_up: u64,
    pub bytes_down: u64,
    pub messages: u64,
    /// Serialized link time: the sum over every individual transfer, as if
    /// all links shared one wire (the pre-federation ledger model).
    pub sim_secs: f64,
    /// Concurrent link time: transfers recorded as one group (a broadcast, a
    /// round of parallel uploads, or one scheduler tick's staged in-round
    /// actor traffic) contribute the *max* of their per-link times — the
    /// wall clock a parallel federation actually experiences.
    pub concurrent_secs: f64,
    /// Bytes that crossed the wire but were discarded by the coordinator —
    /// stale async uploads rejected beyond the staleness bound. Always a
    /// subset of `bytes_up`.
    pub wasted_bytes: u64,
}

/// Timing of a grouped (parallel) set of transfers.
#[derive(Clone, Copy, Debug, Default)]
pub struct GroupTiming {
    /// Sum of per-link transfer times (serialized network).
    pub serial_secs: f64,
    /// Max of per-link transfer times (links run concurrently).
    pub concurrent_secs: f64,
}

#[derive(Default)]
struct NetState {
    pretrain: PhaseCounter,
    train: PhaseCounter,
    eval: PhaseCounter,
    /// Per-link `(seconds, bytes)` staged by trainer actors during the
    /// current scheduler tick, keyed by `(phase, direction, link id)`.
    /// Folded into the counters by [`SimNet::end_tick`].
    tick: HashMap<(Phase, Direction, usize), (f64, u64)>,
    /// Per-call journal of [`SimNet::stage`] entries, kept only when the
    /// stage log is enabled (worker processes): each call's exact size must
    /// survive so the coordinator can replay it call-for-call — replaying a
    /// per-link *sum* would collapse per-call latencies into one.
    stage_log: Vec<(Phase, Direction, usize, u64)>,
    log_stages: bool,
}

impl NetState {
    fn phase_mut(&mut self, p: Phase) -> &mut PhaseCounter {
        match p {
            Phase::PreTrain => &mut self.pretrain,
            Phase::Train => &mut self.train,
            Phase::Eval => &mut self.eval,
        }
    }
}

/// Byte accounting + link model. Shared by reference across the server and
/// all trainer threads.
pub struct SimNet {
    pub cfg: NetConfig,
    state: Mutex<NetState>,
}

impl SimNet {
    pub fn new(cfg: NetConfig) -> SimNet {
        SimNet { cfg, state: Mutex::new(NetState::default()) }
    }

    /// A `SimNet` that journals every [`SimNet::stage`] call so
    /// [`SimNet::take_staged`] can hand the entries to a remote-actor
    /// envelope. Worker processes use this; the coordinator's ledger never
    /// enables the log (its staged traffic folds in place).
    pub fn with_stage_log(cfg: NetConfig) -> SimNet {
        let net = SimNet::new(cfg);
        net.state.lock().unwrap().log_stages = true;
        net
    }

    /// Seconds a transfer of `bytes` takes on one link.
    pub fn transfer_secs(&self, bytes: u64) -> f64 {
        self.cfg.latency_ms / 1e3 + bytes as f64 * 8.0 / (self.cfg.bandwidth_gbps * 1e9)
    }

    /// Record a transfer; returns its simulated duration. The payload itself
    /// moves through ordinary memory (we are in-process) — this call is the
    /// network's *ledger*. A lone transfer is its own "group", so it adds the
    /// same time to both the serial and concurrent accumulators. In-round
    /// traffic issued from inside trainer actors (FedLink's per-step
    /// exchange, BNS-GCN halo re-shipments) should use [`SimNet::stage`]
    /// instead, so the scheduler can fold one tick's parallel links with the
    /// max-over-links rule.
    pub fn send(&self, phase: Phase, dir: Direction, bytes: u64) -> f64 {
        let secs = self.transfer_secs(bytes);
        let mut st = self.state.lock().unwrap();
        let c = st.phase_mut(phase);
        match dir {
            Direction::Up => c.bytes_up += bytes,
            Direction::Down => c.bytes_down += bytes,
        }
        c.messages += 1;
        c.sim_secs += secs;
        c.concurrent_secs += secs;
        secs
    }

    /// Record a group of transfers that happen over independent links at the
    /// same time (one federation round's uploads, or a broadcast). Bytes and
    /// message counts are ledgered per link; serial time adds the sum while
    /// concurrent time adds only the slowest link.
    pub fn send_group(&self, phase: Phase, dir: Direction, sizes: &[u64]) -> GroupTiming {
        if sizes.is_empty() {
            return GroupTiming::default();
        }
        let mut timing = GroupTiming::default();
        let mut st = self.state.lock().unwrap();
        let c = st.phase_mut(phase);
        for &bytes in sizes {
            let secs = self.transfer_secs(bytes);
            match dir {
                Direction::Up => c.bytes_up += bytes,
                Direction::Down => c.bytes_down += bytes,
            }
            c.messages += 1;
            timing.serial_secs += secs;
            timing.concurrent_secs = timing.concurrent_secs.max(secs);
        }
        c.sim_secs += timing.serial_secs;
        c.concurrent_secs += timing.concurrent_secs;
        timing
    }

    /// Stage an in-round transfer issued from inside a trainer actor (BNS-GCN
    /// halo re-shipments, FedLink per-step exchanges). Bytes and message
    /// counts hit the counters immediately — byte totals stay exact and
    /// deterministic — but the link *time* is parked on the current scheduler
    /// tick, keyed by `(phase, direction, link)`. When the coordinator closes
    /// the tick ([`SimNet::end_tick`]), each phase adds the serial sum to
    /// `sim_secs` and only the slowest link to `concurrent_secs`: traffic from
    /// different clients in the same tick runs over independent links, while
    /// repeated sends on one link still serialize (they accumulate in its
    /// entry). This closes the old "`concurrent_secs` is an upper bound for
    /// actor-issued traffic" caveat.
    pub fn stage(&self, phase: Phase, dir: Direction, link: usize, bytes: u64) {
        let secs = self.transfer_secs(bytes);
        let mut st = self.state.lock().unwrap();
        let c = st.phase_mut(phase);
        match dir {
            Direction::Up => c.bytes_up += bytes,
            Direction::Down => c.bytes_down += bytes,
        }
        c.messages += 1;
        let e = st.tick.entry((phase, dir, link)).or_insert((0.0, 0));
        e.0 += secs;
        e.1 += bytes;
        if st.log_stages {
            st.stage_log.push((phase, dir, link, bytes));
        }
    }

    /// Un-stage and return `link`'s journaled [`SimNet::stage`] calls, in
    /// call order. Used by remote trainer actors (worker processes): their
    /// local `SimNet` is only a staging buffer — the entries ride the next
    /// update/metric envelope and are re-staged on the coordinator's
    /// authoritative ledger, so byte totals and tick folding match the
    /// in-process deployment exactly. Counters and tick entries for the link
    /// are reversed here, leaving the local ledger as if the calls never
    /// happened. Requires [`SimNet::with_stage_log`].
    pub fn take_staged(&self, link: usize) -> Vec<(Phase, Direction, u64)> {
        let mut st = self.state.lock().unwrap();
        let mut taken = Vec::new();
        let mut kept = Vec::new();
        for entry in std::mem::take(&mut st.stage_log) {
            if entry.2 == link {
                taken.push(entry);
            } else {
                kept.push(entry);
            }
        }
        st.stage_log = kept;
        for &(phase, dir, _, bytes) in &taken {
            let secs = self.transfer_secs(bytes);
            if let Some(e) = st.tick.get_mut(&(phase, dir, link)) {
                // Reversal is exact: the same bytes produce the same f64.
                e.0 -= secs;
                e.1 = e.1.saturating_sub(bytes);
                if e.0 <= 0.0 && e.1 == 0 {
                    st.tick.remove(&(phase, dir, link));
                }
            }
            let c = st.phase_mut(phase);
            match dir {
                Direction::Up => c.bytes_up = c.bytes_up.saturating_sub(bytes),
                Direction::Down => c.bytes_down = c.bytes_down.saturating_sub(bytes),
            }
            c.messages = c.messages.saturating_sub(1);
        }
        taken.into_iter().map(|(p, d, _, b)| (p, d, b)).collect()
    }

    /// Close the current scheduler tick: fold every staged link into the
    /// counters (serial = sum, concurrent = slowest link per phase). Called
    /// by the federation runtime at the end of each training/eval collection;
    /// a no-op when nothing was staged.
    pub fn end_tick(&self) {
        let mut st = self.state.lock().unwrap();
        if st.tick.is_empty() {
            return;
        }
        let tick = std::mem::take(&mut st.tick);
        for phase in [Phase::PreTrain, Phase::Train, Phase::Eval] {
            let mut sum = 0.0f64;
            let mut slowest = 0.0f64;
            for ((p, _, _), (secs, _)) in &tick {
                if *p == phase {
                    sum += *secs;
                    slowest = slowest.max(*secs);
                }
            }
            if sum > 0.0 {
                let c = st.phase_mut(phase);
                c.sim_secs += sum;
                c.concurrent_secs += slowest;
            }
        }
    }

    /// Mark `bytes` of already-ledgered upload traffic as waste: the
    /// transfer happened (it is in `bytes_up`), but the coordinator rejected
    /// the payload — a stale async update beyond the staleness bound.
    pub fn note_waste(&self, phase: Phase, bytes: u64) {
        let mut st = self.state.lock().unwrap();
        st.phase_mut(phase).wasted_bytes += bytes;
    }

    /// Total wasted (rejected-stale) bytes across all phases.
    pub fn total_wasted_bytes(&self) -> u64 {
        let st = self.state.lock().unwrap();
        st.pretrain.wasted_bytes + st.train.wasted_bytes + st.eval.wasted_bytes
    }

    /// Broadcast accounting helper: the server sends the same `bytes` to
    /// `m` clients (m separate link transfers). Returns the serialized total
    /// for backward compatibility; use [`SimNet::broadcast_timed`] for the
    /// concurrent-link view.
    pub fn broadcast(&self, phase: Phase, bytes: u64, m: usize) -> f64 {
        self.broadcast_timed(phase, bytes, m).serial_secs
    }

    /// Broadcast with both timings: serial (sum over links) and concurrent
    /// (max over links — with identical payloads, one link's time). The
    /// monitor's simulated round time uses the concurrent figure.
    pub fn broadcast_timed(&self, phase: Phase, bytes: u64, m: usize) -> GroupTiming {
        let sizes = vec![bytes; m];
        self.send_group(phase, Direction::Down, &sizes)
    }

    pub fn counter(&self, phase: Phase) -> PhaseCounter {
        let mut st = self.state.lock().unwrap();
        st.phase_mut(phase).clone()
    }

    /// Overwrite one phase's counters wholesale. The resume path
    /// (`Federation::spawn_restored`) re-seeds the ledger from a
    /// `RoundCheckpoint` row *after* the deterministic session rebuild
    /// re-charged its pre-train traffic, so a resumed run's counters
    /// continue bitwise from the snapshot instead of double-counting the
    /// rebuild or losing the snapshotted rounds.
    pub fn restore_counter(&self, phase: Phase, counter: PhaseCounter) {
        let mut st = self.state.lock().unwrap();
        *st.phase_mut(phase) = counter;
    }

    /// Total bytes in both directions across all phases.
    pub fn total_bytes(&self) -> u64 {
        let st = self.state.lock().unwrap();
        [&st.pretrain, &st.train, &st.eval]
            .iter()
            .map(|c| c.bytes_up + c.bytes_down)
            .sum()
    }

    pub fn total_sim_secs(&self) -> f64 {
        let st = self.state.lock().unwrap();
        st.pretrain.sim_secs + st.train.sim_secs + st.eval.sim_secs
    }

    /// Total concurrent-link seconds across all phases (the parallel
    /// federation's simulated network wall clock).
    pub fn total_concurrent_secs(&self) -> f64 {
        let st = self.state.lock().unwrap();
        st.pretrain.concurrent_secs + st.train.concurrent_secs + st.eval.concurrent_secs
    }

    pub fn reset(&self) {
        let mut st = self.state.lock().unwrap();
        let log_stages = st.log_stages;
        *st = NetState { log_stages, ..NetState::default() };
    }
}

// ---------------------------------------------------------------------------
// Measured wire accounting
// ---------------------------------------------------------------------------

/// Measured traffic of one `(phase, direction)` lane of the wire ledger.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WireCounter {
    /// Protocol frames that crossed the transport.
    pub frames: u64,
    /// Total measured frame bytes (control + payload).
    pub bytes: u64,
    /// The data-plane portion as it actually crossed the wire — compressed
    /// when a codec is active (`pack` compresses uploads *and* broadcasts;
    /// `quantized` uploads only). For uncompressed plaintext/DP sessions
    /// `payload_bytes == SimNet bytes` exactly for payload frames;
    /// control frames (Hello, Train, Eval, Metric, Stop, ModelVersion) are
    /// measured in `bytes` but never counted here — matching the protocol's
    /// ledger rule that orchestration is unbilled.
    pub payload_bytes: u64,
    /// The *logical* (uncompressed-equivalent) size of the same payloads:
    /// what they would have cost as plain f32 frames. Equal to
    /// `payload_bytes` without compression; larger under `pack`/`quantized`,
    /// making `payload_bytes / logical_bytes` the measured compression
    /// ratio the report prints. Under `federation.compression: pack` the
    /// SimNet ledger keeps charging this logical size (so `pack` is
    /// ledger-transparent), while `quantized` charges SimNet the compressed
    /// size (the accuracy-vs-bytes axis is the point of that mode).
    pub logical_bytes: u64,
}

/// Measured wire-byte ledger: what the transport backend actually moved, by
/// phase and direction, recorded frame-by-frame by the coordinator's event
/// loop. Lives next to [`SimNet`] (the simulated ledger) so the report can
/// cross-check the two — see the module docs for the invariant.
pub struct WireLedger {
    counters: Mutex<HashMap<(Phase, Direction), WireCounter>>,
}

impl Default for WireLedger {
    fn default() -> Self {
        WireLedger::new()
    }
}

impl WireLedger {
    pub fn new() -> WireLedger {
        WireLedger { counters: Mutex::new(HashMap::new()) }
    }

    /// Count one frame of `len` measured bytes.
    pub fn record_frame(&self, phase: Phase, dir: Direction, len: u64) {
        let mut c = self.counters.lock().unwrap();
        let e = c.entry((phase, dir)).or_default();
        e.frames += 1;
        e.bytes += len;
    }

    /// Mark already-recorded frame traffic as data-plane payload:
    /// `wire_bytes` as measured on the transport (compressed when an upload
    /// codec is active) and `logical_bytes` as the uncompressed-equivalent
    /// plain-f32 size. The two are equal wherever no codec applies.
    pub fn note_payload(&self, phase: Phase, dir: Direction, wire_bytes: u64, logical_bytes: u64) {
        if wire_bytes == 0 && logical_bytes == 0 {
            return;
        }
        let mut c = self.counters.lock().unwrap();
        let e = c.entry((phase, dir)).or_default();
        e.payload_bytes += wire_bytes;
        e.logical_bytes += logical_bytes;
    }

    /// Count a frame that is payload end to end with measured == logical —
    /// uncompressed model broadcasts (SimNet charges the whole encoded
    /// frame). Packed broadcasts instead pair [`WireLedger::record_frame`]
    /// with a [`WireLedger::note_payload`] whose logical size is the raw
    /// `SetModel` frame the pack replaces.
    pub fn record_payload_frame(&self, phase: Phase, dir: Direction, len: u64) {
        let mut c = self.counters.lock().unwrap();
        let e = c.entry((phase, dir)).or_default();
        e.frames += 1;
        e.bytes += len;
        e.payload_bytes += len;
        e.logical_bytes += len;
    }

    pub fn counter(&self, phase: Phase, dir: Direction) -> WireCounter {
        self.counters.lock().unwrap().get(&(phase, dir)).copied().unwrap_or_default()
    }

    /// Total measured bytes across all phases and directions.
    pub fn total_bytes(&self) -> u64 {
        self.counters.lock().unwrap().values().map(|c| c.bytes).sum()
    }

    pub fn total_frames(&self) -> u64 {
        self.counters.lock().unwrap().values().map(|c| c.frames).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_model() {
        let net = SimNet::new(NetConfig { bandwidth_gbps: 1.0, latency_ms: 1.0 });
        // 1 Gbps: 125 MB/s; 125 MB -> 1 s + 1 ms latency
        let secs = net.transfer_secs(125_000_000);
        assert!((secs - 1.001).abs() < 1e-9);
    }

    #[test]
    fn accounting_by_phase_and_direction() {
        let net = SimNet::new(NetConfig::default());
        net.send(Phase::PreTrain, Direction::Up, 1000);
        net.send(Phase::PreTrain, Direction::Up, 500);
        net.send(Phase::Train, Direction::Down, 200);
        let pre = net.counter(Phase::PreTrain);
        assert_eq!(pre.bytes_up, 1500);
        assert_eq!(pre.bytes_down, 0);
        assert_eq!(pre.messages, 2);
        let tr = net.counter(Phase::Train);
        assert_eq!(tr.bytes_down, 200);
        assert_eq!(net.total_bytes(), 1700);
        assert!(net.total_sim_secs() > 0.0);
    }

    #[test]
    fn broadcast_counts_per_client() {
        let net = SimNet::new(NetConfig::default());
        net.broadcast(Phase::Train, 100, 10);
        let c = net.counter(Phase::Train);
        assert_eq!(c.bytes_down, 1000);
        assert_eq!(c.messages, 10);
    }

    #[test]
    fn broadcast_concurrent_time_is_one_link() {
        let net = SimNet::new(NetConfig { bandwidth_gbps: 1.0, latency_ms: 1.0 });
        let t = net.broadcast_timed(Phase::Train, 125_000_000, 10);
        // Serial: 10 links end to end; concurrent: the slowest (= any) link.
        assert!((t.serial_secs - 10.010).abs() < 1e-9, "serial {}", t.serial_secs);
        assert!((t.concurrent_secs - 1.001).abs() < 1e-9, "concurrent {}", t.concurrent_secs);
        let c = net.counter(Phase::Train);
        assert!((c.sim_secs - t.serial_secs).abs() < 1e-12);
        assert!((c.concurrent_secs - t.concurrent_secs).abs() < 1e-12);
    }

    #[test]
    fn send_group_max_vs_sum() {
        let net = SimNet::new(NetConfig { bandwidth_gbps: 1.0, latency_ms: 0.0 });
        let t = net.send_group(Phase::Train, Direction::Up, &[125_000_000, 250_000_000]);
        assert!((t.serial_secs - 3.0).abs() < 1e-9);
        assert!((t.concurrent_secs - 2.0).abs() < 1e-9);
        let c = net.counter(Phase::Train);
        assert_eq!(c.bytes_up, 375_000_000);
        assert_eq!(c.messages, 2);
        // Singles contribute equally to both accumulators.
        net.send(Phase::Train, Direction::Up, 125_000_000);
        let c = net.counter(Phase::Train);
        assert!((c.sim_secs - 4.0).abs() < 1e-9);
        assert!((c.concurrent_secs - 3.0).abs() < 1e-9);
        assert!(net.total_concurrent_secs() <= net.total_sim_secs());
    }

    #[test]
    fn reset_clears() {
        let net = SimNet::new(NetConfig::default());
        net.send(Phase::Eval, Direction::Up, 42);
        net.reset();
        assert_eq!(net.total_bytes(), 0);
    }

    #[test]
    fn staged_tick_groups_links_concurrently() {
        let net = SimNet::new(NetConfig { bandwidth_gbps: 1.0, latency_ms: 0.0 });
        // Two clients ship in the same tick; client 1 sends twice (its two
        // transfers serialize on its own link).
        net.stage(Phase::Train, Direction::Up, 0, 125_000_000); // 1 s
        net.stage(Phase::Train, Direction::Up, 1, 125_000_000); // 1 s
        net.stage(Phase::Train, Direction::Up, 1, 125_000_000); // +1 s, same link
        // Bytes land immediately; time waits for the tick to close.
        let c = net.counter(Phase::Train);
        assert_eq!(c.bytes_up, 375_000_000);
        assert_eq!(c.messages, 3);
        assert_eq!(c.sim_secs, 0.0);
        net.end_tick();
        let c = net.counter(Phase::Train);
        assert!((c.sim_secs - 3.0).abs() < 1e-9, "serial = sum: {}", c.sim_secs);
        assert!(
            (c.concurrent_secs - 2.0).abs() < 1e-9,
            "concurrent = slowest link (client 1's 2s): {}",
            c.concurrent_secs
        );
        // Closing an empty tick is a no-op.
        net.end_tick();
        let c2 = net.counter(Phase::Train);
        assert_eq!(c2.sim_secs, c.sim_secs);
    }

    #[test]
    fn take_staged_replays_call_for_call() {
        // A worker-local net journals stage calls; taking them reverses the
        // local ledger and replaying them on a coordinator net reproduces
        // the in-process totals exactly — including per-call latency.
        let cfg = NetConfig { bandwidth_gbps: 1.0, latency_ms: 1.0 };
        let worker = SimNet::with_stage_log(cfg.clone());
        worker.stage(Phase::Train, Direction::Up, 3, 1000);
        worker.stage(Phase::Train, Direction::Up, 3, 1000);
        worker.stage(Phase::Eval, Direction::Up, 3, 12);
        worker.stage(Phase::Train, Direction::Up, 5, 777); // another link stays
        let taken = worker.take_staged(3);
        assert_eq!(
            taken,
            vec![
                (Phase::Train, Direction::Up, 1000),
                (Phase::Train, Direction::Up, 1000),
                (Phase::Eval, Direction::Up, 12)
            ],
            "entries must come back in call order"
        );
        // Local ledger reversed for link 3, untouched for link 5.
        assert_eq!(worker.counter(Phase::Train).bytes_up, 777);
        assert_eq!(worker.counter(Phase::Eval).bytes_up, 0);
        assert!(worker.take_staged(3).is_empty(), "second take is empty");

        // Replay on the coordinator ledger == direct in-process staging.
        let coord = SimNet::new(cfg.clone());
        for (p, d, b) in &taken {
            coord.stage(*p, *d, 3, *b);
        }
        coord.end_tick();
        let direct = SimNet::new(cfg);
        direct.stage(Phase::Train, Direction::Up, 3, 1000);
        direct.stage(Phase::Train, Direction::Up, 3, 1000);
        direct.stage(Phase::Eval, Direction::Up, 3, 12);
        direct.end_tick();
        for phase in [Phase::Train, Phase::Eval] {
            let a = coord.counter(phase);
            let b = direct.counter(phase);
            assert_eq!(a.bytes_up, b.bytes_up);
            assert_eq!(a.messages, b.messages);
            assert!((a.sim_secs - b.sim_secs).abs() < 1e-12, "{} vs {}", a.sim_secs, b.sim_secs);
            assert!((a.concurrent_secs - b.concurrent_secs).abs() < 1e-12);
        }
    }

    #[test]
    fn wire_ledger_counts_frames_and_payload() {
        let w = WireLedger::new();
        w.record_payload_frame(Phase::Train, Direction::Down, 500);
        w.record_frame(Phase::Train, Direction::Up, 142);
        w.note_payload(Phase::Train, Direction::Up, 100, 100);
        w.record_frame(Phase::Eval, Direction::Down, 9);
        let down = w.counter(Phase::Train, Direction::Down);
        assert_eq!((down.frames, down.bytes, down.payload_bytes), (1, 500, 500));
        assert_eq!(down.logical_bytes, 500, "broadcast frames are payload == logical");
        let up = w.counter(Phase::Train, Direction::Up);
        assert_eq!((up.frames, up.bytes, up.payload_bytes), (1, 142, 100));
        assert_eq!(up.logical_bytes, 100);
        assert_eq!(w.total_bytes(), 651);
        assert_eq!(w.total_frames(), 3);
        assert_eq!(w.counter(Phase::PreTrain, Direction::Up), WireCounter::default());
    }

    #[test]
    fn wire_ledger_splits_compressed_vs_logical_payload() {
        // A compressed upload notes its measured (wire) size next to the
        // logical plain-f32 size; the ratio the report prints is their
        // quotient.
        let w = WireLedger::new();
        w.record_frame(Phase::Train, Direction::Up, 260);
        w.note_payload(Phase::Train, Direction::Up, 250, 1000);
        let up = w.counter(Phase::Train, Direction::Up);
        assert_eq!(up.payload_bytes, 250);
        assert_eq!(up.logical_bytes, 1000);
        assert!(up.payload_bytes < up.logical_bytes, "compression must show a < 1 ratio");
    }

    #[test]
    fn waste_is_a_subset_annotation() {
        let net = SimNet::new(NetConfig::default());
        net.send(Phase::Train, Direction::Up, 1000);
        net.note_waste(Phase::Train, 1000);
        let c = net.counter(Phase::Train);
        assert_eq!(c.bytes_up, 1000);
        assert_eq!(c.wasted_bytes, 1000);
        assert_eq!(net.total_wasted_bytes(), 1000);
    }
}
