//! Simulated federation network.
//!
//! The paper deploys trainers on AWS EKS pods and measures bytes + transfer
//! time between them. Here the trainers are in-process (threads), and this
//! module is the substitute network: every logical transfer passes through
//! [`SimNet::send`], which (a) counts the real serialized bytes by phase and
//! direction, and (b) converts bytes to *simulated* wall-clock seconds with a
//! bandwidth + latency link model. Measured (CPU) time and simulated
//! (network) time are reported separately by the monitor so both the
//! "training time" and "communication cost" axes of Figs 5–10 can be
//! regenerated.

pub mod serialize;

use std::sync::Mutex;

/// Which phase of the pipeline a transfer belongs to (the paper splits
/// communication into pre-train and train; Figs 5/7/9 stack these).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Phase {
    PreTrain,
    Train,
    Eval,
}

impl Phase {
    pub fn name(&self) -> &'static str {
        match self {
            Phase::PreTrain => "pretrain",
            Phase::Train => "train",
            Phase::Eval => "eval",
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Direction {
    /// Client → server.
    Up,
    /// Server → client(s).
    Down,
}

/// Link model.
#[derive(Clone, Debug)]
pub struct NetConfig {
    pub bandwidth_gbps: f64,
    pub latency_ms: f64,
}

impl Default for NetConfig {
    fn default() -> Self {
        // Same-region cloud instances (the paper's EKS testbed).
        NetConfig { bandwidth_gbps: 1.0, latency_ms: 1.0 }
    }
}

#[derive(Clone, Debug, Default)]
pub struct PhaseCounter {
    pub bytes_up: u64,
    pub bytes_down: u64,
    pub messages: u64,
    pub sim_secs: f64,
}

#[derive(Default)]
struct NetState {
    pretrain: PhaseCounter,
    train: PhaseCounter,
    eval: PhaseCounter,
}

impl NetState {
    fn phase_mut(&mut self, p: Phase) -> &mut PhaseCounter {
        match p {
            Phase::PreTrain => &mut self.pretrain,
            Phase::Train => &mut self.train,
            Phase::Eval => &mut self.eval,
        }
    }
}

/// Byte accounting + link model. Shared by reference across the server and
/// all trainer threads.
pub struct SimNet {
    pub cfg: NetConfig,
    state: Mutex<NetState>,
}

impl SimNet {
    pub fn new(cfg: NetConfig) -> SimNet {
        SimNet { cfg, state: Mutex::new(NetState::default()) }
    }

    /// Seconds a transfer of `bytes` takes on one link.
    pub fn transfer_secs(&self, bytes: u64) -> f64 {
        self.cfg.latency_ms / 1e3 + bytes as f64 * 8.0 / (self.cfg.bandwidth_gbps * 1e9)
    }

    /// Record a transfer; returns its simulated duration. The payload itself
    /// moves through ordinary memory (we are in-process) — this call is the
    /// network's *ledger*.
    pub fn send(&self, phase: Phase, dir: Direction, bytes: u64) -> f64 {
        let secs = self.transfer_secs(bytes);
        let mut st = self.state.lock().unwrap();
        let c = st.phase_mut(phase);
        match dir {
            Direction::Up => c.bytes_up += bytes,
            Direction::Down => c.bytes_down += bytes,
        }
        c.messages += 1;
        c.sim_secs += secs;
        secs
    }

    /// Broadcast accounting helper: the server sends the same `bytes` to
    /// `m` clients (m separate link transfers).
    pub fn broadcast(&self, phase: Phase, bytes: u64, m: usize) -> f64 {
        let mut total = 0.0;
        for _ in 0..m {
            total += self.send(phase, Direction::Down, bytes);
        }
        total
    }

    pub fn counter(&self, phase: Phase) -> PhaseCounter {
        let mut st = self.state.lock().unwrap();
        st.phase_mut(phase).clone()
    }

    /// Total bytes in both directions across all phases.
    pub fn total_bytes(&self) -> u64 {
        let st = self.state.lock().unwrap();
        [&st.pretrain, &st.train, &st.eval]
            .iter()
            .map(|c| c.bytes_up + c.bytes_down)
            .sum()
    }

    pub fn total_sim_secs(&self) -> f64 {
        let st = self.state.lock().unwrap();
        st.pretrain.sim_secs + st.train.sim_secs + st.eval.sim_secs
    }

    pub fn reset(&self) {
        *self.state.lock().unwrap() = NetState::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_model() {
        let net = SimNet::new(NetConfig { bandwidth_gbps: 1.0, latency_ms: 1.0 });
        // 1 Gbps: 125 MB/s; 125 MB -> 1 s + 1 ms latency
        let secs = net.transfer_secs(125_000_000);
        assert!((secs - 1.001).abs() < 1e-9);
    }

    #[test]
    fn accounting_by_phase_and_direction() {
        let net = SimNet::new(NetConfig::default());
        net.send(Phase::PreTrain, Direction::Up, 1000);
        net.send(Phase::PreTrain, Direction::Up, 500);
        net.send(Phase::Train, Direction::Down, 200);
        let pre = net.counter(Phase::PreTrain);
        assert_eq!(pre.bytes_up, 1500);
        assert_eq!(pre.bytes_down, 0);
        assert_eq!(pre.messages, 2);
        let tr = net.counter(Phase::Train);
        assert_eq!(tr.bytes_down, 200);
        assert_eq!(net.total_bytes(), 1700);
        assert!(net.total_sim_secs() > 0.0);
    }

    #[test]
    fn broadcast_counts_per_client() {
        let net = SimNet::new(NetConfig::default());
        net.broadcast(Phase::Train, 100, 10);
        let c = net.counter(Phase::Train);
        assert_eq!(c.bytes_down, 1000);
        assert_eq!(c.messages, 10);
    }

    #[test]
    fn reset_clears() {
        let net = SimNet::new(NetConfig::default());
        net.send(Phase::Eval, Direction::Up, 42);
        net.reset();
        assert_eq!(net.total_bytes(), 0);
    }
}
