//! TCP transport backend: the multi-process deployment fabric.
//!
//! The in-memory [`super::link::ChannelTransport`] moves frames between
//! threads; this module moves the *same* frames between processes (or
//! machines) over sockets, so trainer actors can run as `fedgraph worker`
//! processes — the paper's "scalable deployment across multiple physical
//! machines" claim made literal. The complete wire reference (this framing,
//! the `WorkerHello → Assign` handshake with its wire-codec negotiation —
//! upload encoders plus the downlink `SetModelPacked` decoder — and the
//! ledger invariants) lives in `docs/WIRE_FORMAT.md`.
//!
//! ## Socket framing
//!
//! Every protocol frame is wrapped in a fixed 16-byte header:
//!
//! ```text
//! | len: u32 LE | client: u32 LE | fnv1a(len‖client‖payload): u64 LE | payload |
//! ```
//!
//! - `len` is the payload length (capped at [`MAX_FRAME_BYTES`] so a
//!   corrupted length can never trigger an absurd allocation);
//! - `client` is the lane tag: one worker connection multiplexes all of its
//!   assigned trainers' duplex lanes ([`CONTROL_LANE`] tags the pre-lane
//!   `WorkerHello → Assign` handshake);
//! - the checksum covers the **header fields and** the payload, so line
//!   corruption anywhere in a frame — including a flipped lane tag, which
//!   would otherwise silently misroute — surfaces as
//!   [`WireError::BadChecksum`]/`Truncated`, never a mis-parsed or
//!   mis-delivered protocol message (the payload carries the wire format's
//!   *own* trailer too; the frame checksum just fails earlier and cheaper).
//!
//! ## Threading
//!
//! The coordinator keeps one **reader thread per worker connection**, each
//! feeding the shared incoming mpsc lane — exactly the shape of the channel
//! backend, which is what keeps [`super::link::CoordLink::try_recv`]
//! non-blocking (the async round policy polls it). Workers keep one demux
//! reader per connection that routes frames to per-client actor mailboxes.
//! Writes go through [`write_frame`] with exclusive access per direction
//! (the coordinator owns its write halves; worker actors share one via a
//! mutex), so frames never interleave.

use std::io::{Read, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::link::{CoordLink, Frame, TrainerLink};
use super::serialize::WireError;

/// Lane tag for pre-rendezvous worker-level control frames
/// (`WorkerHello` / `Assign`).
pub const CONTROL_LANE: u32 = u32::MAX;

/// Hard cap on one frame's payload: a corrupted header length fails fast
/// instead of asking the allocator for gigabytes.
pub const MAX_FRAME_BYTES: u32 = 1 << 30;

const HEADER_BYTES: usize = 16;

// ---------------------------------------------------------------------------
// Pure frame codec (unit- and property-tested without sockets)
// ---------------------------------------------------------------------------

/// FNV-1a over `len ‖ client ‖ payload` — the frame checksum covers the
/// header fields too, so a flipped lane tag or length can never pass.
fn frame_checksum(len: u32, client: u32, payload: &[u8]) -> u64 {
    const PRIME: u64 = 0x100000001b3;
    let mut h = 0xcbf29ce484222325u64;
    for b in len.to_le_bytes().into_iter().chain(client.to_le_bytes()) {
        h ^= b as u64;
        h = h.wrapping_mul(PRIME);
    }
    for &b in payload {
        h ^= b as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

/// Encode one socket frame: header + payload.
pub fn encode_frame(client: u32, payload: &[u8]) -> Vec<u8> {
    let len = payload.len() as u32;
    let mut out = Vec::with_capacity(HEADER_BYTES + payload.len());
    out.extend_from_slice(&len.to_le_bytes());
    out.extend_from_slice(&client.to_le_bytes());
    out.extend_from_slice(&frame_checksum(len, client, payload).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// Decode one socket frame from the front of `buf`. Returns
/// `(client, payload, bytes consumed)`. Truncated input yields
/// [`WireError::Truncated`], an oversize length or checksum mismatch
/// anywhere in the frame (header fields included) yields
/// [`WireError::BadChecksum`] — never a panic.
pub fn decode_frame(buf: &[u8]) -> Result<(u32, &[u8], usize), WireError> {
    if buf.len() < HEADER_BYTES {
        return Err(WireError::Truncated);
    }
    let len = u32::from_le_bytes(buf[0..4].try_into().unwrap());
    if len > MAX_FRAME_BYTES {
        return Err(WireError::BadChecksum);
    }
    let client = u32::from_le_bytes(buf[4..8].try_into().unwrap());
    let sum = u64::from_le_bytes(buf[8..16].try_into().unwrap());
    let total = HEADER_BYTES + len as usize;
    if buf.len() < total {
        return Err(WireError::Truncated);
    }
    let payload = &buf[HEADER_BYTES..total];
    if frame_checksum(len, client, payload) != sum {
        return Err(WireError::BadChecksum);
    }
    Ok((client, payload, total))
}

/// Write one frame to a stream (single `write_all`, so concurrent writers
/// holding exclusive access never interleave partial frames).
pub fn write_frame(w: &mut impl Write, client: u32, payload: &[u8]) -> std::io::Result<()> {
    let _sp = crate::trace::span("io", "frame_send")
        .arg("lane", client)
        .arg("bytes", payload.len());
    w.write_all(&encode_frame(client, payload))
}

/// What [`read_frame`] saw on the stream.
pub enum ReadOutcome {
    Frame(u32, Vec<u8>),
    /// Orderly close at a frame boundary.
    Closed,
}

/// Read one frame from a stream. EOF at a frame boundary is an orderly
/// [`ReadOutcome::Closed`]; EOF mid-frame, a bad length, or a checksum
/// mismatch is an error.
pub fn read_frame(r: &mut impl Read) -> Result<ReadOutcome> {
    // The span covers the blocking wait for the header too, so reader-thread
    // lanes show idle-on-socket time, not just copy time.
    let mut sp = crate::trace::span("io", "frame_recv");
    let mut header = [0u8; HEADER_BYTES];
    // Distinguish orderly close (0 bytes at a boundary) from truncation.
    let mut got = 0usize;
    while got < HEADER_BYTES {
        match r.read(&mut header[got..]) {
            Ok(0) if got == 0 => return Ok(ReadOutcome::Closed),
            Ok(0) => bail!("wire: {}", WireError::Truncated),
            Ok(k) => got += k,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(anyhow!("wire read: {e}")),
        }
    }
    let len = u32::from_le_bytes(header[0..4].try_into().unwrap());
    if len > MAX_FRAME_BYTES {
        bail!("wire: frame length {len} exceeds cap");
    }
    let client = u32::from_le_bytes(header[4..8].try_into().unwrap());
    let sum = u64::from_le_bytes(header[8..16].try_into().unwrap());
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload).map_err(|e| {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            anyhow!("wire: {}", WireError::Truncated)
        } else {
            anyhow!("wire read: {e}")
        }
    })?;
    if frame_checksum(len, client, &payload) != sum {
        bail!("wire: {}", WireError::BadChecksum);
    }
    sp = sp.arg("lane", client).arg("bytes", payload.len());
    drop(sp);
    Ok(ReadOutcome::Frame(client, payload))
}

/// Connect with retries (the coordinator may not have bound its listener yet
/// when a worker starts — normal in multi-process launches).
pub fn connect_with_retry(addr: &str, timeout: Duration) -> Result<TcpStream> {
    let deadline = Instant::now() + timeout;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => {
                s.set_nodelay(true).ok();
                return Ok(s);
            }
            Err(e) => {
                if Instant::now() >= deadline {
                    bail!("cannot connect to coordinator at {addr}: {e}");
                }
                std::thread::sleep(Duration::from_millis(100));
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Coordinator side
// ---------------------------------------------------------------------------

type TaggedFrame = (usize, Result<Frame, String>);

/// Coordinator endpoint over `W` worker connections: per-lane sends routed to
/// the owning connection's write half; one reader thread per connection feeds
/// the shared incoming mpsc lane (non-blocking `try_recv` preserved).
pub struct TcpCoord {
    writers: Vec<TcpStream>,
    /// client index → connection index.
    conn_of: Vec<usize>,
    up: Receiver<TaggedFrame>,
    readers: Vec<JoinHandle<()>>,
}

/// Build the coordinator link from handshaken worker connections.
/// `conns[k] = (stream, clients assigned to worker k)`; every client in
/// `0..n` must be covered exactly once.
pub fn coord_link(conns: Vec<(TcpStream, Vec<u32>)>, n: usize) -> Result<Box<dyn CoordLink>> {
    let mut conn_of = vec![usize::MAX; n];
    for (k, (_, clients)) in conns.iter().enumerate() {
        for &c in clients {
            let c = c as usize;
            if c >= n || conn_of[c] != usize::MAX {
                bail!("bad worker assignment: client {c} (n={n})");
            }
            conn_of[c] = k;
        }
    }
    if let Some(missing) = conn_of.iter().position(|&k| k == usize::MAX) {
        bail!("client {missing} is not assigned to any worker connection");
    }
    let (up_tx, up_rx) = channel::<TaggedFrame>();
    let mut writers = Vec::with_capacity(conns.len());
    let mut readers = Vec::new();
    for (k, (stream, clients)) in conns.into_iter().enumerate() {
        stream.set_nodelay(true).ok();
        let mut read_half = stream.try_clone().map_err(|e| anyhow!("clone conn {k}: {e}"))?;
        writers.push(stream);
        let tx = up_tx.clone();
        let first_client = clients.first().copied().unwrap_or(0) as usize;
        let handle = std::thread::Builder::new()
            .name(format!("fed-tcp-reader-{k}"))
            .spawn(move || loop {
                match read_frame(&mut read_half) {
                    Ok(ReadOutcome::Frame(client, payload)) => {
                        if tx.send((client as usize, Ok(payload.into()))).is_err() {
                            return; // coordinator gone
                        }
                    }
                    Ok(ReadOutcome::Closed) => return,
                    Err(e) => {
                        // Surface line corruption as a trainer failure so the
                        // coordinator aborts with a clear error instead of
                        // waiting on a frame that will never arrive.
                        let _ = tx.send((first_client, Err(format!("{e:#}"))));
                        return;
                    }
                }
            })
            .map_err(|e| anyhow!("spawning tcp reader {k}: {e}"))?;
        readers.push(handle);
    }
    Ok(Box::new(TcpCoord { writers, conn_of, up: up_rx, readers }))
}

impl CoordLink for TcpCoord {
    fn send(&mut self, client: usize, frame: Frame) -> Result<()> {
        let &conn = self
            .conn_of
            .get(client)
            .ok_or_else(|| anyhow!("no such trainer {client}"))?;
        write_frame(&mut self.writers[conn], client as u32, &frame)
            .map_err(|_| anyhow!("trainer {client} hung up"))
    }

    fn recv(&mut self) -> Result<(usize, Frame)> {
        match self.up.recv() {
            Ok((from, Ok(frame))) => Ok((from, frame)),
            Ok((from, Err(e))) => Err(anyhow!("worker connection of trainer {from}: {e}")),
            Err(_) => Err(anyhow!("all trainers hung up")),
        }
    }

    fn try_recv(&mut self) -> Result<Option<(usize, Frame)>> {
        match self.up.try_recv() {
            Ok((from, Ok(frame))) => Ok(Some((from, frame))),
            Ok((from, Err(e))) => Err(anyhow!("worker connection of trainer {from}: {e}")),
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => Err(anyhow!("all trainers hung up")),
        }
    }
}

impl Drop for TcpCoord {
    fn drop(&mut self) {
        // FIN both directions so worker demux readers unblock, then collect
        // our own readers (they exit on the workers' FIN or ours).
        for w in &self.writers {
            let _ = w.shutdown(Shutdown::Both);
        }
        for h in self.readers.drain(..) {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Worker side
// ---------------------------------------------------------------------------

/// Trainer endpoint inside a worker process: sends tag frames with the
/// client index and share the connection's write half; receives come from the
/// demux reader's per-client mailbox.
pub struct TcpTrainer {
    client: u32,
    writer: Arc<Mutex<TcpStream>>,
    down: Receiver<Frame>,
    /// Shared with the demux reader: frames enqueued but not yet received.
    queue_gauge: Arc<AtomicU64>,
}

impl TrainerLink for TcpTrainer {
    fn send(&mut self, frame: Frame) -> Result<()> {
        let mut w = self.writer.lock().unwrap();
        write_frame(&mut *w, self.client, &frame).map_err(|_| anyhow!("coordinator hung up"))
    }

    fn recv(&mut self) -> Result<Frame> {
        let frame = self.down.recv().map_err(|_| anyhow!("coordinator hung up"))?;
        decrement_gauge(&self.queue_gauge);
        Ok(frame)
    }
}

fn decrement_gauge(g: &AtomicU64) {
    // Never underflow: a racing sampler may read between paired ops.
    let _ = g.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1));
}

/// Build one [`TrainerLink`] per assigned client over a handshaken worker
/// connection, plus the demux reader thread handle. The caller keeps the
/// original stream to `shutdown` it when the session ends. `queue_gauge`
/// (see [`crate::trace::ProcessStats::queue_gauge`]) counts frames sitting
/// in actor mailboxes — incremented on demux enqueue, decremented on
/// trainer receive — feeding the worker's `MetricsSnapshot.queue_depth`.
pub fn worker_links(
    stream: &TcpStream,
    clients: &[usize],
    queue_gauge: Arc<AtomicU64>,
) -> Result<(Vec<Box<dyn TrainerLink>>, JoinHandle<()>)> {
    stream.set_nodelay(true).ok();
    let writer = Arc::new(Mutex::new(stream.try_clone().map_err(|e| anyhow!("clone: {e}"))?));
    let mut read_half = stream.try_clone().map_err(|e| anyhow!("clone: {e}"))?;
    let mut senders: std::collections::HashMap<u32, Sender<Frame>> =
        std::collections::HashMap::new();
    let mut links: Vec<Box<dyn TrainerLink>> = Vec::with_capacity(clients.len());
    for &c in clients {
        let (tx, rx) = channel::<Frame>();
        senders.insert(c as u32, tx);
        links.push(Box::new(TcpTrainer {
            client: c as u32,
            writer: writer.clone(),
            down: rx,
            queue_gauge: queue_gauge.clone(),
        }));
    }
    let reader = std::thread::Builder::new()
        .name("fed-tcp-demux".to_string())
        .spawn(move || loop {
            match read_frame(&mut read_half) {
                Ok(ReadOutcome::Frame(client, payload)) => {
                    match senders.get(&client) {
                        // A dropped receiver means that actor already exited;
                        // remaining actors keep their lanes.
                        Some(tx) => {
                            queue_gauge.fetch_add(1, Ordering::Relaxed);
                            if tx.send(payload.into()).is_err() {
                                decrement_gauge(&queue_gauge);
                            }
                        }
                        None => eprintln!("fedgraph worker: frame for unassigned lane {client}"),
                    }
                }
                Ok(ReadOutcome::Closed) => return, // coordinator done; senders drop
                Err(e) => {
                    eprintln!("fedgraph worker: wire error, closing lanes: {e:#}");
                    return;
                }
            }
        })
        .map_err(|e| anyhow!("spawning worker demux reader: {e}"))?;
    Ok((links, reader))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    #[test]
    fn frame_codec_roundtrip() {
        for payload in [&b""[..], &b"x"[..], &[0xAB; 1000][..]] {
            let bytes = encode_frame(7, payload);
            let (client, got, used) = decode_frame(&bytes).unwrap();
            assert_eq!(client, 7);
            assert_eq!(got, payload);
            assert_eq!(used, bytes.len());
        }
        // Two frames back to back parse sequentially.
        let mut buf = encode_frame(1, b"first");
        buf.extend_from_slice(&encode_frame(2, b"second"));
        let (c1, p1, used) = decode_frame(&buf).unwrap();
        assert_eq!((c1, p1), (1, &b"first"[..]));
        let (c2, p2, _) = decode_frame(&buf[used..]).unwrap();
        assert_eq!((c2, p2), (2, &b"second"[..]));
    }

    #[test]
    fn frame_codec_rejects_corruption_and_truncation() {
        let bytes = encode_frame(3, b"payload-bytes");
        for cut in [0, 5, HEADER_BYTES, bytes.len() - 1] {
            assert!(
                matches!(decode_frame(&bytes[..cut]), Err(WireError::Truncated)),
                "cut at {cut} must be Truncated"
            );
        }
        let mut corrupt = bytes.clone();
        corrupt[HEADER_BYTES + 2] ^= 0x40; // payload flip
        assert!(matches!(decode_frame(&corrupt), Err(WireError::BadChecksum)));
        // A flipped lane tag must fail the checksum, not silently misroute.
        let mut misrouted = bytes.clone();
        misrouted[4] ^= 0x01;
        assert!(matches!(decode_frame(&misrouted), Err(WireError::BadChecksum)));
        let mut oversize = bytes;
        oversize[0..4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_frame(&oversize).is_err());
    }

    #[test]
    fn stream_reader_detects_orderly_close_vs_truncation() {
        let bytes = encode_frame(1, b"hello");
        let mut full: &[u8] = &bytes;
        match read_frame(&mut full).unwrap() {
            ReadOutcome::Frame(c, p) => {
                assert_eq!(c, 1);
                assert_eq!(p, b"hello");
            }
            ReadOutcome::Closed => panic!("frame expected"),
        }
        // Clean EOF at the boundary.
        assert!(matches!(read_frame(&mut full).unwrap(), ReadOutcome::Closed));
        // EOF mid-frame is an error.
        let mut cut: &[u8] = &bytes[..bytes.len() - 2];
        assert!(read_frame(&mut cut).is_err());
    }

    #[test]
    fn loopback_lanes_roundtrip_and_preserve_fifo() {
        // 1 worker hosting clients {0, 1}; coordinator on the other side.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let worker_stream = std::thread::spawn(move || TcpStream::connect(addr).unwrap());
        let (coord_stream, _) = listener.accept().unwrap();
        let worker_stream = worker_stream.join().unwrap();

        let mut coord = coord_link(vec![(coord_stream, vec![0, 1])], 2).unwrap();
        let gauge = Arc::new(AtomicU64::new(0));
        let (mut links, demux) = worker_links(&worker_stream, &[0, 1], gauge.clone()).unwrap();

        // Coordinator → per-client lanes, FIFO per lane.
        coord.send(0, b"a0".to_vec().into()).unwrap();
        coord.send(0, b"a1".to_vec().into()).unwrap();
        coord.send(1, b"b0".to_vec().into()).unwrap();
        assert_eq!(&*links[0].recv().unwrap(), b"a0");
        assert_eq!(&*links[0].recv().unwrap(), b"a1");
        assert_eq!(&*links[1].recv().unwrap(), b"b0");
        // Every enqueued frame has been received: the depth gauge is back
        // to zero (demux increments, trainer recv decrements).
        assert_eq!(gauge.load(Ordering::Relaxed), 0);

        // Trainer → coordinator with source tagging.
        links[1].send(b"up1".to_vec().into()).unwrap();
        let (from, frame) = coord.recv().unwrap();
        assert_eq!(from, 1);
        assert_eq!(&*frame, b"up1");

        // try_recv polls without blocking.
        assert!(coord.try_recv().unwrap().is_none());
        links[0].send(b"up0".to_vec().into()).unwrap();
        // The frame takes a moment to cross the socket + reader thread.
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            if let Some((from, frame)) = coord.try_recv().unwrap() {
                assert_eq!(from, 0);
                assert_eq!(&*frame, b"up0");
                break;
            }
            assert!(Instant::now() < deadline, "try_recv never saw the frame");
            std::thread::sleep(Duration::from_millis(1));
        }

        // Orderly teardown: dropping the coordinator FINs the socket and the
        // worker demux exits; trainer recv reports the coordinator gone.
        drop(coord);
        demux.join().unwrap();
        assert!(links[0].recv().is_err());
        let _ = worker_stream.shutdown(Shutdown::Both);
    }

    #[test]
    fn coord_link_rejects_bad_assignments() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || TcpStream::connect(addr).unwrap());
        let (s, _) = listener.accept().unwrap();
        let _client = t.join().unwrap();
        // Client 1 missing.
        assert!(coord_link(vec![(s, vec![0])], 2).is_err());
    }
}
