//! TCP transport backend: the multi-process deployment fabric.
//!
//! The in-memory [`super::link::ChannelTransport`] moves frames between
//! threads; this module moves the *same* frames between processes (or
//! machines) over sockets, so trainer actors can run as `fedgraph worker`
//! processes — the paper's "scalable deployment across multiple physical
//! machines" claim made literal. The complete wire reference (this framing,
//! the `WorkerHello → Assign` handshake with its wire-codec negotiation —
//! upload encoders plus the downlink `SetModelPacked` decoder — and the
//! ledger invariants) lives in `docs/WIRE_FORMAT.md`.
//!
//! ## Socket framing
//!
//! Every protocol frame is wrapped in a fixed 16-byte header:
//!
//! ```text
//! | len: u32 LE | client: u32 LE | fnv1a(len‖client‖payload): u64 LE | payload |
//! ```
//!
//! - `len` is the payload length (capped at [`MAX_FRAME_BYTES`] so a
//!   corrupted length can never trigger an absurd allocation);
//! - `client` is the lane tag: one worker connection multiplexes all of its
//!   assigned trainers' duplex lanes ([`CONTROL_LANE`] tags the pre-lane
//!   `WorkerHello → Assign` handshake);
//! - the checksum covers the **header fields and** the payload, so line
//!   corruption anywhere in a frame — including a flipped lane tag, which
//!   would otherwise silently misroute — surfaces as
//!   [`WireError::BadChecksum`]/`Truncated`, never a mis-parsed or
//!   mis-delivered protocol message (the payload carries the wire format's
//!   *own* trailer too; the frame checksum just fails earlier and cheaper).
//!
//! ## Threading
//!
//! The coordinator keeps one **reader thread per worker connection**, each
//! feeding the shared incoming mpsc lane — exactly the shape of the channel
//! backend, which is what keeps [`super::link::CoordLink::try_recv`]
//! non-blocking (the async round policy polls it). Workers keep one demux
//! reader per connection that routes frames to per-client actor mailboxes.
//! Writes go through [`write_frame`] with exclusive access per direction
//! (the coordinator owns its write halves; worker actors share one via a
//! mutex), so frames never interleave.

use std::io::{Read, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::link::{CoordLink, Frame, TrainerLink};
use super::serialize::WireError;

/// Lane tag for pre-rendezvous worker-level control frames
/// (`WorkerHello` / `Assign`) and, since protocol v6, in-run control traffic:
/// heartbeats (an *empty* payload on this lane — pure liveness, filtered by
/// the coordinator's reader threads and never surfaced), `Reassign` orders
/// and their acks.
pub const CONTROL_LANE: u32 = u32::MAX;

/// Hard cap on one frame's payload: a corrupted header length fails fast
/// instead of asking the allocator for gigabytes.
pub const MAX_FRAME_BYTES: u32 = 1 << 30;

const HEADER_BYTES: usize = 16;

// ---------------------------------------------------------------------------
// Pure frame codec (unit- and property-tested without sockets)
// ---------------------------------------------------------------------------

/// FNV-1a over `len ‖ client ‖ payload` — the frame checksum covers the
/// header fields too, so a flipped lane tag or length can never pass.
fn frame_checksum(len: u32, client: u32, payload: &[u8]) -> u64 {
    const PRIME: u64 = 0x100000001b3;
    let mut h = 0xcbf29ce484222325u64;
    for b in len.to_le_bytes().into_iter().chain(client.to_le_bytes()) {
        h ^= b as u64;
        h = h.wrapping_mul(PRIME);
    }
    for &b in payload {
        h ^= b as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

/// Encode one socket frame: header + payload.
pub fn encode_frame(client: u32, payload: &[u8]) -> Vec<u8> {
    let len = payload.len() as u32;
    let mut out = Vec::with_capacity(HEADER_BYTES + payload.len());
    out.extend_from_slice(&len.to_le_bytes());
    out.extend_from_slice(&client.to_le_bytes());
    out.extend_from_slice(&frame_checksum(len, client, payload).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// Decode one socket frame from the front of `buf`. Returns
/// `(client, payload, bytes consumed)`. Truncated input yields
/// [`WireError::Truncated`], an oversize length or checksum mismatch
/// anywhere in the frame (header fields included) yields
/// [`WireError::BadChecksum`] — never a panic.
pub fn decode_frame(buf: &[u8]) -> Result<(u32, &[u8], usize), WireError> {
    if buf.len() < HEADER_BYTES {
        return Err(WireError::Truncated);
    }
    let len = u32::from_le_bytes(buf[0..4].try_into().unwrap());
    if len > MAX_FRAME_BYTES {
        return Err(WireError::BadChecksum);
    }
    let client = u32::from_le_bytes(buf[4..8].try_into().unwrap());
    let sum = u64::from_le_bytes(buf[8..16].try_into().unwrap());
    let total = HEADER_BYTES + len as usize;
    if buf.len() < total {
        return Err(WireError::Truncated);
    }
    let payload = &buf[HEADER_BYTES..total];
    if frame_checksum(len, client, payload) != sum {
        return Err(WireError::BadChecksum);
    }
    Ok((client, payload, total))
}

/// Write one frame to a stream (single `write_all`, so concurrent writers
/// holding exclusive access never interleave partial frames).
pub fn write_frame(w: &mut impl Write, client: u32, payload: &[u8]) -> std::io::Result<()> {
    let _sp = crate::trace::span("io", "frame_send")
        .arg("lane", client)
        .arg("bytes", payload.len());
    w.write_all(&encode_frame(client, payload))
}

/// What [`read_frame`] saw on the stream.
pub enum ReadOutcome {
    Frame(u32, Vec<u8>),
    /// Orderly close at a frame boundary.
    Closed,
}

/// Read one frame from a stream. EOF at a frame boundary is an orderly
/// [`ReadOutcome::Closed`]; EOF mid-frame, a bad length, or a checksum
/// mismatch is an error.
pub fn read_frame(r: &mut impl Read) -> Result<ReadOutcome> {
    // The span covers the blocking wait for the header too, so reader-thread
    // lanes show idle-on-socket time, not just copy time.
    let mut sp = crate::trace::span("io", "frame_recv");
    let mut header = [0u8; HEADER_BYTES];
    // Distinguish orderly close (0 bytes at a boundary) from truncation.
    let mut got = 0usize;
    while got < HEADER_BYTES {
        match r.read(&mut header[got..]) {
            Ok(0) if got == 0 => return Ok(ReadOutcome::Closed),
            Ok(0) => bail!("wire: {}", WireError::Truncated),
            Ok(k) => got += k,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(anyhow!("wire read: {e}")),
        }
    }
    let len = u32::from_le_bytes(header[0..4].try_into().unwrap());
    if len > MAX_FRAME_BYTES {
        bail!("wire: frame length {len} exceeds cap");
    }
    let client = u32::from_le_bytes(header[4..8].try_into().unwrap());
    let sum = u64::from_le_bytes(header[8..16].try_into().unwrap());
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload).map_err(|e| {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            anyhow!("wire: {}", WireError::Truncated)
        } else {
            anyhow!("wire read: {e}")
        }
    })?;
    if frame_checksum(len, client, &payload) != sum {
        bail!("wire: {}", WireError::BadChecksum);
    }
    sp = sp.arg("lane", client).arg("bytes", payload.len());
    drop(sp);
    Ok(ReadOutcome::Frame(client, payload))
}

/// The connect retry budget ran out without reaching a listener. Typed (and
/// carried inside the `anyhow` chain) so callers — and the worker's
/// regression tests — can distinguish "coordinator never appeared" from
/// handshake failures.
#[derive(Debug, Clone)]
pub struct ConnectTimeout {
    pub addr: String,
    pub attempts: u32,
    pub last_error: String,
}

impl std::fmt::Display for ConnectTimeout {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "cannot connect to coordinator at {} after {} attempt(s): {}",
            self.addr, self.attempts, self.last_error
        )
    }
}

impl std::error::Error for ConnectTimeout {}

/// Connect with retries (the coordinator may not have bound its listener yet
/// when a worker starts — normal in multi-process launches; this also
/// covers the worker-before-coordinator `ECONNREFUSED` race). Retries back
/// off exponentially from `base` doubling to the `cap`, with ±25 % jitter
/// so a respawned worker fleet doesn't stampede the listener in lockstep,
/// while the overall wait stays bounded by `budget`. Running out of budget
/// returns a typed [`ConnectTimeout`] inside the error chain.
pub fn connect_with_backoff(
    addr: &str,
    base: Duration,
    cap: Duration,
    budget: Duration,
) -> Result<TcpStream> {
    let deadline = Instant::now() + budget;
    let mut backoff = base.max(Duration::from_millis(1));
    let cap = cap.max(backoff);
    let mut attempts = 0u32;
    // Jitter stream: seeded per (process, address), so parallel workers and
    // successive respawns of the same worker each walk different schedules.
    let addr_hash = addr.bytes().fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x1000_0000_01b3)
    });
    let mut jitter = crate::util::rng::Rng::seeded(crate::util::rng::hash_u64(
        std::process::id() as u64,
        addr_hash,
        0xBACC_0FF,
    ));
    loop {
        attempts += 1;
        match TcpStream::connect(addr) {
            Ok(s) => {
                s.set_nodelay(true).ok();
                return Ok(s);
            }
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(anyhow::Error::new(ConnectTimeout {
                        addr: addr.to_string(),
                        attempts,
                        last_error: e.to_string(),
                    }));
                }
                // ±25 % of the nominal delay, never past the deadline.
                let nominal = backoff.as_millis() as u64;
                let jittered = nominal * 3 / 4 + jitter.below((nominal / 2 + 1) as usize) as u64;
                let sleep = Duration::from_millis(jittered.max(1))
                    .min(deadline.saturating_duration_since(Instant::now()));
                std::thread::sleep(sleep);
                backoff = (backoff * 2).min(cap);
            }
        }
    }
}

/// [`connect_with_backoff`] on the long-standing default schedule: 100 ms
/// doubling to a 2 s cap, bounded by `timeout`.
pub fn connect_with_retry(addr: &str, timeout: Duration) -> Result<TcpStream> {
    connect_with_backoff(addr, Duration::from_millis(100), Duration::from_secs(2), timeout)
}

/// Send one heartbeat: an empty payload on [`CONTROL_LANE`]. The
/// coordinator's reader threads treat any bytes as proof of life and filter
/// these frames out before routing, so heartbeats never reach the protocol
/// layer or the ledger.
pub fn write_heartbeat(w: &mut impl Write) -> std::io::Result<()> {
    w.write_all(&encode_frame(CONTROL_LANE, &[]))
}

/// Spawn the worker-side heartbeat pulse: one empty [`CONTROL_LANE`] frame
/// every `interval` on the shared write half until `stop` is raised or the
/// socket dies. Shares the write mutex with trainer lanes so frames never
/// interleave.
pub fn spawn_heartbeat(
    writer: Arc<Mutex<TcpStream>>,
    interval: Duration,
    stop: Arc<AtomicBool>,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name("fed-tcp-heartbeat".to_string())
        .spawn(move || loop {
            if stop.load(Ordering::Relaxed) {
                return;
            }
            {
                let mut w = writer.lock().unwrap();
                if write_heartbeat(&mut *w).is_err() {
                    return; // socket gone; the demux reader reports it
                }
            }
            std::thread::sleep(interval);
        })
        .expect("spawning heartbeat thread")
}

// ---------------------------------------------------------------------------
// Coordinator side
// ---------------------------------------------------------------------------

/// A worker connection is dead: socket EOF (clean or mid-frame), wire
/// corruption, a failed write, or heartbeat silence past the liveness
/// window. Carried inside the `anyhow` error chain of
/// [`CoordLink::recv`]/`send` so the federation runtime can `downcast_ref`
/// it and run recovery instead of aborting. `clients` is the lane set the
/// connection hosted *when the reader started* — diagnostics only; the
/// runtime recomputes the authoritative set from its own assignment table
/// (lanes may have been rerouted since).
#[derive(Debug, Clone)]
pub struct WorkerGone {
    pub conn: usize,
    pub clients: Vec<usize>,
    pub reason: String,
}

impl std::fmt::Display for WorkerGone {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "worker connection {} gone (hosted lanes {:?}): {}",
            self.conn, self.clients, self.reason
        )
    }
}

impl std::error::Error for WorkerGone {}

type TaggedFrame = (usize, Result<Frame, WorkerGone>);

/// One connection's reader loop. With a liveness window the socket gets a
/// short read timeout and the loop accumulates raw bytes, parsing complete
/// frames with the pure [`decode_frame`] codec: any received byte counts as
/// proof of life, empty [`CONTROL_LANE`] frames (heartbeats) are filtered
/// here, and silence longer than the window raises [`WorkerGone`]. Without
/// a window the loop blocks on [`read_frame`] (low-level tests, channel
/// parity). Either way EOF and wire corruption surface as [`WorkerGone`] —
/// the runtime decides whether that is fatal or recoverable.
fn reader_loop(
    mut read_half: TcpStream,
    conn: usize,
    clients: Vec<usize>,
    liveness: Option<Duration>,
    tx: Sender<TaggedFrame>,
) {
    let gone = |reason: String| WorkerGone { conn, clients: clients.clone(), reason };
    let window = match liveness {
        Some(w) => w,
        None => loop {
            match read_frame(&mut read_half) {
                Ok(ReadOutcome::Frame(client, payload)) => {
                    if client == CONTROL_LANE && payload.is_empty() {
                        continue; // heartbeat
                    }
                    if tx.send((client as usize, Ok(payload.into()))).is_err() {
                        return; // coordinator gone
                    }
                }
                Ok(ReadOutcome::Closed) => {
                    let _ =
                        tx.send((CONTROL_LANE as usize, Err(gone("connection closed".into()))));
                    return;
                }
                Err(e) => {
                    let _ = tx.send((CONTROL_LANE as usize, Err(gone(format!("{e:#}")))));
                    return;
                }
            }
        },
    };
    // Poll at a fraction of the window so detection lags death by at most
    // ~window + one poll.
    let poll = (window / 4).max(Duration::from_millis(10));
    read_half.set_read_timeout(Some(poll)).ok();
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = vec![0u8; 64 * 1024];
    let mut last_seen = Instant::now();
    loop {
        match read_half.read(&mut chunk) {
            Ok(0) => {
                let reason = if buf.is_empty() {
                    "connection closed".to_string()
                } else {
                    format!("connection closed mid-frame ({} buffered bytes)", buf.len())
                };
                let _ = tx.send((CONTROL_LANE as usize, Err(gone(reason))));
                return;
            }
            Ok(k) => {
                last_seen = Instant::now();
                buf.extend_from_slice(&chunk[..k]);
                loop {
                    match decode_frame(&buf) {
                        Ok((client, payload, used)) => {
                            let heartbeat = client == CONTROL_LANE && payload.is_empty();
                            if !heartbeat {
                                let frame: Frame = payload.to_vec().into();
                                if tx.send((client as usize, Ok(frame))).is_err() {
                                    return;
                                }
                            }
                            buf.drain(..used);
                        }
                        Err(WireError::Truncated) => break, // need more bytes
                        Err(e) => {
                            let _ = tx
                                .send((CONTROL_LANE as usize, Err(gone(format!("wire: {e}")))));
                            return;
                        }
                    }
                }
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if last_seen.elapsed() > window {
                    let _ = tx.send((
                        CONTROL_LANE as usize,
                        Err(gone(format!("liveness timeout ({window:?} of silence)"))),
                    ));
                    return;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => {
                let _ = tx.send((CONTROL_LANE as usize, Err(gone(format!("read: {e}")))));
                return;
            }
        }
    }
}

/// Coordinator endpoint over worker connections: per-lane sends routed to
/// the owning connection's write half; one reader thread per connection feeds
/// the shared incoming mpsc lane (non-blocking `try_recv` preserved).
/// Since protocol v6 the set of connections and the lane→connection routing
/// are both mutable: [`CoordLink::add_conn`] admits a late worker,
/// [`CoordLink::reroute`] migrates lanes after a death or at an elastic
/// round boundary.
pub struct TcpCoord {
    writers: Vec<TcpStream>,
    /// client index → connection index.
    conn_of: Vec<usize>,
    /// connection index → hosted client indices (kept in sync by `reroute`).
    conn_clients: Vec<Vec<usize>>,
    liveness: Option<Duration>,
    up: Receiver<TaggedFrame>,
    /// Kept to hand reader threads of late-added connections; also means
    /// `recv` never sees a disconnected channel — end-of-stream arrives as
    /// per-connection [`WorkerGone`] errors instead.
    up_tx: Sender<TaggedFrame>,
    readers: Vec<JoinHandle<()>>,
}

impl TcpCoord {
    fn worker_gone(&self, conn: usize, reason: String) -> anyhow::Error {
        anyhow::Error::new(WorkerGone {
            conn,
            clients: self.conn_clients.get(conn).cloned().unwrap_or_default(),
            reason,
        })
    }
}

/// Build the coordinator link from handshaken worker connections.
/// `conns[k] = (stream, clients assigned to worker k)`; every client in
/// `0..n` must be covered exactly once. `liveness` is the fault-detection
/// window (`federation.fault_tolerance.worker_timeout_ms`): `Some` arms
/// heartbeat/timeout detection on every connection, `None` keeps the
/// legacy blocking readers (failures still surface as [`WorkerGone`], just
/// without a timeout).
pub fn coord_link(
    conns: Vec<(TcpStream, Vec<u32>)>,
    n: usize,
    liveness: Option<Duration>,
) -> Result<Box<dyn CoordLink>> {
    let mut conn_of = vec![usize::MAX; n];
    for (k, (_, clients)) in conns.iter().enumerate() {
        for &c in clients {
            let c = c as usize;
            if c >= n || conn_of[c] != usize::MAX {
                bail!("bad worker assignment: client {c} (n={n})");
            }
            conn_of[c] = k;
        }
    }
    if let Some(missing) = conn_of.iter().position(|&k| k == usize::MAX) {
        bail!("client {missing} is not assigned to any worker connection");
    }
    let (up_tx, up_rx) = channel::<TaggedFrame>();
    let mut writers = Vec::with_capacity(conns.len());
    let mut conn_clients = Vec::with_capacity(conns.len());
    let mut readers = Vec::new();
    for (k, (stream, clients)) in conns.into_iter().enumerate() {
        stream.set_nodelay(true).ok();
        let read_half = stream.try_clone().map_err(|e| anyhow!("clone conn {k}: {e}"))?;
        writers.push(stream);
        let hosted: Vec<usize> = clients.iter().map(|&c| c as usize).collect();
        conn_clients.push(hosted.clone());
        let tx = up_tx.clone();
        let handle = std::thread::Builder::new()
            .name(format!("fed-tcp-reader-{k}"))
            .spawn(move || reader_loop(read_half, k, hosted, liveness, tx))
            .map_err(|e| anyhow!("spawning tcp reader {k}: {e}"))?;
        readers.push(handle);
    }
    Ok(Box::new(TcpCoord {
        writers,
        conn_of,
        conn_clients,
        liveness,
        up: up_rx,
        up_tx,
        readers,
    }))
}

impl CoordLink for TcpCoord {
    fn send(&mut self, client: usize, frame: Frame) -> Result<()> {
        let &conn = self
            .conn_of
            .get(client)
            .filter(|&&k| k != usize::MAX)
            .ok_or_else(|| anyhow!("no such trainer {client}"))?;
        write_frame(&mut self.writers[conn], client as u32, &frame)
            .map_err(|e| self.worker_gone(conn, format!("write to lane {client} failed: {e}")))
    }

    fn recv(&mut self) -> Result<(usize, Frame)> {
        match self.up.recv() {
            Ok((from, Ok(frame))) => Ok((from, frame)),
            Ok((_, Err(gone))) => Err(anyhow::Error::new(gone)),
            Err(_) => Err(anyhow!("all trainers hung up")),
        }
    }

    fn try_recv(&mut self) -> Result<Option<(usize, Frame)>> {
        match self.up.try_recv() {
            Ok((from, Ok(frame))) => Ok(Some((from, frame))),
            Ok((_, Err(gone))) => Err(anyhow::Error::new(gone)),
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => Err(anyhow!("all trainers hung up")),
        }
    }

    fn send_control(&mut self, conn: usize, frame: Frame) -> Result<()> {
        if conn >= self.writers.len() {
            bail!("no such worker connection {conn}");
        }
        write_frame(&mut self.writers[conn], CONTROL_LANE, &frame)
            .map_err(|e| self.worker_gone(conn, format!("control write failed: {e}")))
    }

    fn reroute(&mut self, clients: &[usize], conn: usize) -> Result<()> {
        if conn >= self.writers.len() {
            bail!("no such worker connection {conn}");
        }
        for &c in clients {
            if c >= self.conn_of.len() {
                bail!("no such trainer {c}");
            }
        }
        for &c in clients {
            let old = self.conn_of[c];
            if old != usize::MAX && old < self.conn_clients.len() {
                self.conn_clients[old].retain(|&x| x != c);
            }
            self.conn_of[c] = conn;
            self.conn_clients[conn].push(c);
        }
        Ok(())
    }

    fn add_conn(&mut self, stream: TcpStream) -> Result<usize> {
        stream.set_nodelay(true).ok();
        let k = self.writers.len();
        let read_half = stream.try_clone().map_err(|e| anyhow!("clone conn {k}: {e}"))?;
        self.writers.push(stream);
        self.conn_clients.push(Vec::new());
        let tx = self.up_tx.clone();
        let liveness = self.liveness;
        let handle = std::thread::Builder::new()
            .name(format!("fed-tcp-reader-{k}"))
            .spawn(move || reader_loop(read_half, k, Vec::new(), liveness, tx))
            .map_err(|e| anyhow!("spawning tcp reader {k}: {e}"))?;
        self.readers.push(handle);
        Ok(k)
    }
}

impl Drop for TcpCoord {
    fn drop(&mut self) {
        // FIN both directions so worker demux readers unblock, then collect
        // our own readers (they exit on the workers' FIN or ours).
        for w in &self.writers {
            let _ = w.shutdown(Shutdown::Both);
        }
        for h in self.readers.drain(..) {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Worker side
// ---------------------------------------------------------------------------

/// Trainer endpoint inside a worker process: sends tag frames with the
/// client index and share the connection's write half; receives come from the
/// demux reader's per-client mailbox.
pub struct TcpTrainer {
    client: u32,
    writer: Arc<Mutex<TcpStream>>,
    down: Receiver<Frame>,
    /// Shared with the demux reader: frames enqueued but not yet received.
    queue_gauge: Arc<AtomicU64>,
}

impl TrainerLink for TcpTrainer {
    fn send(&mut self, frame: Frame) -> Result<()> {
        let mut w = self.writer.lock().unwrap();
        write_frame(&mut *w, self.client, &frame).map_err(|_| anyhow!("coordinator hung up"))
    }

    fn recv(&mut self) -> Result<Frame> {
        let frame = self.down.recv().map_err(|_| anyhow!("coordinator hung up"))?;
        decrement_gauge(&self.queue_gauge);
        Ok(frame)
    }
}

fn decrement_gauge(g: &AtomicU64) {
    // Never underflow: a racing sampler may read between paired ops.
    let _ = g.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1));
}

/// The worker's dynamic lane table: the shared write half plus the demux
/// routing map. Protocol v6 made lane membership mutable mid-session — a
/// `Reassign` order adds clients to a running worker — so lanes are opened
/// through this registry (under a mutex the demux reader shares) instead of
/// a frozen map built at connect time.
#[derive(Clone)]
pub struct LaneRegistry {
    writer: Arc<Mutex<TcpStream>>,
    senders: Arc<Mutex<std::collections::HashMap<u32, Sender<Frame>>>>,
    queue_gauge: Arc<AtomicU64>,
}

impl LaneRegistry {
    /// Open (or re-open) the duplex lane for `client` and return its trainer
    /// endpoint. Must be called before the coordinator's first frame for the
    /// lane (the recovery protocol guarantees this: lanes are registered
    /// before `ReassignAck` is sent, and the coordinator waits for the ack).
    pub fn open_lane(&self, client: usize) -> Box<dyn TrainerLink> {
        let (tx, rx) = channel::<Frame>();
        self.senders.lock().unwrap().insert(client as u32, tx);
        Box::new(TcpTrainer {
            client: client as u32,
            writer: self.writer.clone(),
            down: rx,
            queue_gauge: self.queue_gauge.clone(),
        })
    }

    /// The connection's shared write half — for control-lane sends
    /// (`ReassignAck`) and the heartbeat pulse, which must serialize with
    /// trainer-lane writes.
    pub fn writer(&self) -> Arc<Mutex<TcpStream>> {
        self.writer.clone()
    }
}

/// Build one [`TrainerLink`] per assigned client over a handshaken worker
/// connection, plus the [`LaneRegistry`] for opening more lanes later, the
/// control-frame mailbox (coordinator [`CONTROL_LANE`] frames — `Reassign`
/// orders; its sender drops when the demux reader exits, which is the
/// worker's connection-closed signal), and the demux reader thread handle.
/// The caller keeps the original stream to `shutdown` it when the session
/// ends. `queue_gauge` (see [`crate::trace::ProcessStats::queue_gauge`])
/// counts frames sitting in actor mailboxes — incremented on demux enqueue,
/// decremented on trainer receive — feeding the worker's
/// `MetricsSnapshot.queue_depth`.
pub fn worker_links(
    stream: &TcpStream,
    clients: &[usize],
    queue_gauge: Arc<AtomicU64>,
) -> Result<(Vec<Box<dyn TrainerLink>>, LaneRegistry, Receiver<Frame>, JoinHandle<()>)> {
    stream.set_nodelay(true).ok();
    let writer = Arc::new(Mutex::new(stream.try_clone().map_err(|e| anyhow!("clone: {e}"))?));
    let mut read_half = stream.try_clone().map_err(|e| anyhow!("clone: {e}"))?;
    let registry = LaneRegistry {
        writer,
        senders: Arc::new(Mutex::new(std::collections::HashMap::new())),
        queue_gauge: queue_gauge.clone(),
    };
    let mut links: Vec<Box<dyn TrainerLink>> = Vec::with_capacity(clients.len());
    for &c in clients {
        links.push(registry.open_lane(c));
    }
    let (control_tx, control_rx) = channel::<Frame>();
    let senders = registry.senders.clone();
    let reader = std::thread::Builder::new()
        .name("fed-tcp-demux".to_string())
        .spawn(move || loop {
            match read_frame(&mut read_half) {
                Ok(ReadOutcome::Frame(client, payload)) => {
                    if client == CONTROL_LANE {
                        // Control frames go to the worker's serve loop; a
                        // dropped receiver means it already exited.
                        let _ = control_tx.send(payload.into());
                        continue;
                    }
                    match senders.lock().unwrap().get(&client) {
                        // A dropped receiver means that actor already exited;
                        // remaining actors keep their lanes.
                        Some(tx) => {
                            queue_gauge.fetch_add(1, Ordering::Relaxed);
                            if tx.send(payload.into()).is_err() {
                                decrement_gauge(&queue_gauge);
                            }
                        }
                        None => eprintln!("fedgraph worker: frame for unassigned lane {client}"),
                    }
                }
                Ok(ReadOutcome::Closed) => return, // coordinator done; senders drop
                Err(e) => {
                    eprintln!("fedgraph worker: wire error, closing lanes: {e:#}");
                    return;
                }
            }
        })
        .map_err(|e| anyhow!("spawning worker demux reader: {e}"))?;
    Ok((links, registry, control_rx, reader))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    #[test]
    fn frame_codec_roundtrip() {
        for payload in [&b""[..], &b"x"[..], &[0xAB; 1000][..]] {
            let bytes = encode_frame(7, payload);
            let (client, got, used) = decode_frame(&bytes).unwrap();
            assert_eq!(client, 7);
            assert_eq!(got, payload);
            assert_eq!(used, bytes.len());
        }
        // Two frames back to back parse sequentially.
        let mut buf = encode_frame(1, b"first");
        buf.extend_from_slice(&encode_frame(2, b"second"));
        let (c1, p1, used) = decode_frame(&buf).unwrap();
        assert_eq!((c1, p1), (1, &b"first"[..]));
        let (c2, p2, _) = decode_frame(&buf[used..]).unwrap();
        assert_eq!((c2, p2), (2, &b"second"[..]));
    }

    #[test]
    fn frame_codec_rejects_corruption_and_truncation() {
        let bytes = encode_frame(3, b"payload-bytes");
        for cut in [0, 5, HEADER_BYTES, bytes.len() - 1] {
            assert!(
                matches!(decode_frame(&bytes[..cut]), Err(WireError::Truncated)),
                "cut at {cut} must be Truncated"
            );
        }
        let mut corrupt = bytes.clone();
        corrupt[HEADER_BYTES + 2] ^= 0x40; // payload flip
        assert!(matches!(decode_frame(&corrupt), Err(WireError::BadChecksum)));
        // A flipped lane tag must fail the checksum, not silently misroute.
        let mut misrouted = bytes.clone();
        misrouted[4] ^= 0x01;
        assert!(matches!(decode_frame(&misrouted), Err(WireError::BadChecksum)));
        let mut oversize = bytes;
        oversize[0..4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_frame(&oversize).is_err());
    }

    #[test]
    fn connect_succeeds_when_listener_appears_within_budget() {
        // Regression: a worker started before the coordinator binds must
        // retry through ECONNREFUSED, not fail on the first attempt. Pick a
        // port while nothing listens, then bring the listener up mid-budget.
        let probe = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = probe.local_addr().unwrap();
        drop(probe); // now refusing connections
        let binder = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(300));
            let listener = TcpListener::bind(addr).unwrap();
            listener.accept().unwrap()
        });
        let stream = connect_with_backoff(
            &addr.to_string(),
            Duration::from_millis(20),
            Duration::from_millis(200),
            Duration::from_secs(10),
        )
        .expect("late listener must be reachable within the budget");
        drop(stream);
        binder.join().unwrap();
    }

    #[test]
    fn connect_times_out_with_a_typed_error_when_no_listener_ever_appears() {
        let probe = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = probe.local_addr().unwrap().to_string();
        drop(probe);
        let start = Instant::now();
        let err = connect_with_backoff(
            &addr,
            Duration::from_millis(10),
            Duration::from_millis(50),
            Duration::from_millis(250),
        )
        .expect_err("no listener must exhaust the budget");
        let timeout =
            err.downcast_ref::<ConnectTimeout>().expect("error must downcast to ConnectTimeout");
        assert_eq!(timeout.addr, addr);
        assert!(timeout.attempts >= 2, "budget allows several attempts, got {}", timeout.attempts);
        assert!(start.elapsed() >= Duration::from_millis(250), "must use the whole budget");
    }

    #[test]
    fn stream_reader_detects_orderly_close_vs_truncation() {
        let bytes = encode_frame(1, b"hello");
        let mut full: &[u8] = &bytes;
        match read_frame(&mut full).unwrap() {
            ReadOutcome::Frame(c, p) => {
                assert_eq!(c, 1);
                assert_eq!(p, b"hello");
            }
            ReadOutcome::Closed => panic!("frame expected"),
        }
        // Clean EOF at the boundary.
        assert!(matches!(read_frame(&mut full).unwrap(), ReadOutcome::Closed));
        // EOF mid-frame is an error.
        let mut cut: &[u8] = &bytes[..bytes.len() - 2];
        assert!(read_frame(&mut cut).is_err());
    }

    #[test]
    fn loopback_lanes_roundtrip_and_preserve_fifo() {
        // 1 worker hosting clients {0, 1}; coordinator on the other side.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let worker_stream = std::thread::spawn(move || TcpStream::connect(addr).unwrap());
        let (coord_stream, _) = listener.accept().unwrap();
        let worker_stream = worker_stream.join().unwrap();

        let mut coord = coord_link(vec![(coord_stream, vec![0, 1])], 2, None).unwrap();
        let gauge = Arc::new(AtomicU64::new(0));
        let (mut links, _registry, _control, demux) =
            worker_links(&worker_stream, &[0, 1], gauge.clone()).unwrap();

        // Coordinator → per-client lanes, FIFO per lane.
        coord.send(0, b"a0".to_vec().into()).unwrap();
        coord.send(0, b"a1".to_vec().into()).unwrap();
        coord.send(1, b"b0".to_vec().into()).unwrap();
        assert_eq!(&*links[0].recv().unwrap(), b"a0");
        assert_eq!(&*links[0].recv().unwrap(), b"a1");
        assert_eq!(&*links[1].recv().unwrap(), b"b0");
        // Every enqueued frame has been received: the depth gauge is back
        // to zero (demux increments, trainer recv decrements).
        assert_eq!(gauge.load(Ordering::Relaxed), 0);

        // Trainer → coordinator with source tagging.
        links[1].send(b"up1".to_vec().into()).unwrap();
        let (from, frame) = coord.recv().unwrap();
        assert_eq!(from, 1);
        assert_eq!(&*frame, b"up1");

        // try_recv polls without blocking.
        assert!(coord.try_recv().unwrap().is_none());
        links[0].send(b"up0".to_vec().into()).unwrap();
        // The frame takes a moment to cross the socket + reader thread.
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            if let Some((from, frame)) = coord.try_recv().unwrap() {
                assert_eq!(from, 0);
                assert_eq!(&*frame, b"up0");
                break;
            }
            assert!(Instant::now() < deadline, "try_recv never saw the frame");
            std::thread::sleep(Duration::from_millis(1));
        }

        // Orderly teardown: dropping the coordinator FINs the socket and the
        // worker demux exits; trainer recv reports the coordinator gone.
        drop(coord);
        demux.join().unwrap();
        assert!(links[0].recv().is_err());
        let _ = worker_stream.shutdown(Shutdown::Both);
    }

    #[test]
    fn coord_link_rejects_bad_assignments() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || TcpStream::connect(addr).unwrap());
        let (s, _) = listener.accept().unwrap();
        let _client = t.join().unwrap();
        // Client 1 missing.
        assert!(coord_link(vec![(s, vec![0])], 2, None).is_err());
    }

    fn loopback_pair() -> (TcpStream, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || TcpStream::connect(addr).unwrap());
        let (coord_side, _) = listener.accept().unwrap();
        (coord_side, t.join().unwrap())
    }

    #[test]
    fn closed_connection_surfaces_as_worker_gone() {
        let (coord_stream, worker_stream) = loopback_pair();
        let mut coord = coord_link(vec![(coord_stream, vec![0])], 1, None).unwrap();
        worker_stream.shutdown(Shutdown::Both).unwrap();
        let err = coord.recv().unwrap_err();
        let gone = err.downcast_ref::<WorkerGone>().expect("typed WorkerGone");
        assert_eq!(gone.conn, 0);
        assert_eq!(gone.clients, vec![0]);
    }

    #[test]
    fn heartbeats_keep_a_silent_worker_alive_and_are_filtered() {
        let (coord_stream, worker_stream) = loopback_pair();
        // 200 ms liveness window; the worker sends nothing but heartbeats.
        let mut coord =
            coord_link(vec![(coord_stream, vec![0])], 1, Some(Duration::from_millis(200)))
                .unwrap();
        let writer = Arc::new(Mutex::new(worker_stream.try_clone().unwrap()));
        let stop = Arc::new(AtomicBool::new(false));
        let hb = spawn_heartbeat(writer.clone(), Duration::from_millis(50), stop.clone());
        // Well past the window, the connection is still healthy and no
        // heartbeat frame has been surfaced as traffic.
        std::thread::sleep(Duration::from_millis(600));
        assert!(coord.try_recv().unwrap().is_none(), "heartbeats must be filtered");
        // A real frame still gets through between heartbeats.
        {
            let mut w = writer.lock().unwrap();
            write_frame(&mut *w, 0, b"payload").unwrap();
        }
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            if let Some((from, frame)) = coord.try_recv().unwrap() {
                assert_eq!(from, 0);
                assert_eq!(&*frame, b"payload");
                break;
            }
            assert!(Instant::now() < deadline, "frame never arrived");
            std::thread::sleep(Duration::from_millis(5));
        }
        // Stop the pulse: silence past the window now raises WorkerGone.
        stop.store(true, Ordering::Relaxed);
        hb.join().unwrap();
        let err = coord.recv().unwrap_err();
        let gone = err.downcast_ref::<WorkerGone>().expect("typed WorkerGone");
        assert!(gone.reason.contains("liveness timeout"), "reason: {}", gone.reason);
        let _ = worker_stream.shutdown(Shutdown::Both);
    }

    #[test]
    fn reroute_and_control_sends_follow_the_lane_table() {
        let (coord_a, worker_a) = loopback_pair();
        let (coord_b, worker_b) = loopback_pair();
        let mut coord =
            coord_link(vec![(coord_a, vec![0]), (coord_b, vec![1])], 2, None).unwrap();
        let gauge = Arc::new(AtomicU64::new(0));
        let (mut links_a, registry_a, _ctl_a, _demux_a) =
            worker_links(&worker_a, &[0], gauge.clone()).unwrap();
        let (mut links_b, registry_b, ctl_b, _demux_b) =
            worker_links(&worker_b, &[1], gauge.clone()).unwrap();

        // Control frames land in the control mailbox, not a trainer lane.
        coord.send_control(1, b"ctl".to_vec().into()).unwrap();
        assert_eq!(&*ctl_b.recv().unwrap(), b"ctl");

        // Migrate client 0 to connection 1: the worker opens the lane, the
        // coordinator reroutes, and traffic flows over the new connection.
        let mut moved = registry_b.open_lane(0);
        coord.reroute(&[0], 1).unwrap();
        coord.send(0, b"after-move".to_vec().into()).unwrap();
        assert_eq!(&*moved.recv().unwrap(), b"after-move");
        moved.send(b"up-from-new-home".to_vec().into()).unwrap();
        let (from, frame) = coord.recv().unwrap();
        assert_eq!(from, 0);
        assert_eq!(&*frame, b"up-from-new-home");

        drop(links_a.pop());
        drop(links_b.pop());
        let _ = registry_a.writer();
        let _ = worker_a.shutdown(Shutdown::Both);
        let _ = worker_b.shutdown(Shutdown::Both);
    }
}
