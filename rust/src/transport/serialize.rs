//! Wire format for federation traffic, plus the pluggable upload codecs.
//!
//! Every payload that crosses the (simulated) network is actually serialized
//! to bytes and parsed back on the receiving side, so (a) the byte counts the
//! monitor reports are real, and (b) serialization cost shows up in measured
//! time exactly as it would in the paper's gRPC/Ray transport. Format:
//! little-endian, length-prefixed sections, FNV-1a checksum trailer. The full
//! byte layout (framing, handshake, and codec negotiation) is documented in
//! `docs/WIRE_FORMAT.md`.
//!
//! ## Wire codecs (`federation.compression`, `federation.entropy`)
//!
//! Model payloads may additionally pass through one of two codecs before
//! they are framed (selected by `federation.compression`; both operate on
//! the *flattened* parameter vector against a shared base). The lossless
//! `pack` codec runs in **both directions**: uploads delta against the
//! broadcast the client trained from, and `SetModelPacked` downlink
//! broadcasts delta against the last version the coordinator sent that
//! client:
//!
//! - [`pack_delta`] / [`unpack_delta`] — **lossless** (`compression: pack`).
//!   The upload's f32 bit patterns are XORed against the base broadcast's,
//!   the 32-bit delta words are split into four byte planes, and each plane
//!   is zero-run-length encoded (varint run lengths). Because a trained
//!   model stays close to its broadcast, the sign/exponent plane (and often
//!   the high mantissa plane) is mostly zeros. Decoding XORs back against
//!   the same base, so the reconstruction is **bit-exact** — `compression:
//!   pack` changes measured wire bytes and nothing else. An incompressible
//!   delta falls back to a raw encoding, so the blob never exceeds the raw
//!   values by more than the 5-byte header.
//! - [`quantize_delta`] / [`dequantize_delta`] — **lossy, opt-in**
//!   (`compression: quantized`). The upload delta is affine-quantized per
//!   [`QUANT_CHUNK`]-value chunk to int8 or int4 codes (`lo + step * code`
//!   with `lo`/`step` shipped as f32 per chunk). Dequantization is
//!   deterministic — the client computes the identical dequantized delta to
//!   maintain its error-feedback residual, so client and coordinator agree
//!   bit-for-bit on what the wire carried.
//!
//! Behind the byte-plane pack sits an optional **entropy stage**
//! (`federation.entropy: rans`): [`pack_delta_rans`] passes each plane's
//! RLE token stream through a static-model byte-wise rANS coder
//! ([`rans_encode`] / [`rans_decode`]) with the per-plane frequency table
//! serialized in the blob header. The blob self-describes via its mode
//! byte, so [`unpack_delta`] decodes all pack variants with no extra
//! parameter — and, like everything else here, the stage is lossless and
//! only changes measured wire bytes.
//!
//! Both codecs are pure byte transforms with typed [`WireError`] failures:
//! truncated or malformed blobs surface as errors, never panics (property
//! tests in `tests/proptests.rs` pin this).

/// FNV-1a 64-bit checksum.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[derive(Debug)]
pub enum WireError {
    Truncated,
    BadChecksum,
    BadTag(u8),
    /// Structurally invalid content behind a valid header (length
    /// inconsistencies, overrunning run-length tokens, trailing bytes).
    Malformed(&'static str),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated => write!(f, "truncated message"),
            WireError::BadChecksum => write!(f, "checksum mismatch"),
            WireError::BadTag(t) => write!(f, "unknown tag {t}"),
            WireError::Malformed(what) => write!(f, "malformed message: {what}"),
        }
    }
}

impl std::error::Error for WireError {}

pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Writer {
        Writer { buf: Vec::new() }
    }

    pub fn with_capacity(cap: usize) -> Writer {
        Writer { buf: Vec::with_capacity(cap) }
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Bulk f32 slice: length prefix + raw LE bytes (single memcpy on LE
    /// targets — this is the hot path for model updates).
    pub fn f32s(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        // SAFETY-free path: f32::to_le_bytes per element would be slow; on
        // little-endian targets the in-memory layout already matches.
        #[cfg(target_endian = "little")]
        {
            let bytes =
                unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) };
            self.buf.extend_from_slice(bytes);
        }
        #[cfg(not(target_endian = "little"))]
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    pub fn i64s(&mut self, v: &[i64]) {
        self.u32(v.len() as u32);
        #[cfg(target_endian = "little")]
        {
            let bytes =
                unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 8) };
            self.buf.extend_from_slice(bytes);
        }
        #[cfg(not(target_endian = "little"))]
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    pub fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Length-prefixed opaque byte blob (nested documents, e.g. an encoded
    /// config inside an `Assign` handshake frame).
    pub fn blob(&mut self, bytes: &[u8]) {
        self.u32(bytes.len() as u32);
        self.buf.extend_from_slice(bytes);
    }

    /// Finalize: append the checksum trailer and return the wire bytes.
    pub fn finish(mut self) -> Vec<u8> {
        let sum = fnv1a(&self.buf);
        self.buf.extend_from_slice(&sum.to_le_bytes());
        self.buf
    }
}

pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    /// Verify the checksum trailer and open a reader over the payload.
    pub fn open(buf: &'a [u8]) -> Result<Reader<'a>, WireError> {
        if buf.len() < 8 {
            return Err(WireError::Truncated);
        }
        let (payload, trailer) = buf.split_at(buf.len() - 8);
        let expect = u64::from_le_bytes(trailer.try_into().unwrap());
        if fnv1a(payload) != expect {
            return Err(WireError::BadChecksum);
        }
        Ok(Reader { buf: payload, pos: 0 })
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.pos + n > self.buf.len() {
            return Err(WireError::Truncated);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32, WireError> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f32s(&mut self) -> Result<Vec<f32>, WireError> {
        let n = self.u32()? as usize;
        let raw = self.take(n * 4)?;
        let mut out = vec![0f32; n];
        #[cfg(target_endian = "little")]
        unsafe {
            std::ptr::copy_nonoverlapping(raw.as_ptr(), out.as_mut_ptr() as *mut u8, n * 4);
        }
        #[cfg(not(target_endian = "little"))]
        for i in 0..n {
            out[i] = f32::from_le_bytes(raw[i * 4..i * 4 + 4].try_into().unwrap());
        }
        Ok(out)
    }

    pub fn i64s(&mut self) -> Result<Vec<i64>, WireError> {
        let n = self.u32()? as usize;
        let raw = self.take(n * 8)?;
        let mut out = vec![0i64; n];
        #[cfg(target_endian = "little")]
        unsafe {
            std::ptr::copy_nonoverlapping(raw.as_ptr(), out.as_mut_ptr() as *mut u8, n * 8);
        }
        #[cfg(not(target_endian = "little"))]
        for i in 0..n {
            out[i] = i64::from_le_bytes(raw[i * 8..i * 8 + 8].try_into().unwrap());
        }
        Ok(out)
    }

    pub fn str(&mut self) -> Result<String, WireError> {
        let n = self.u32()? as usize;
        let raw = self.take(n)?;
        Ok(String::from_utf8_lossy(raw).into_owned())
    }

    pub fn blob(&mut self) -> Result<Vec<u8>, WireError> {
        let n = self.u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

/// Exact wire length of [`encode_params`] for tensors of these lengths,
/// without serializing: count prefix (4) + per tensor (4-byte length prefix
/// + 4 bytes/value) + checksum trailer (8). The federation ledger charges
/// plaintext model uploads at this size — the data-plane payload alone,
/// excluding the update envelope's telemetry fields.
pub fn params_wire_len(tensor_lens: impl Iterator<Item = usize>) -> u64 {
    let body: u64 = tensor_lens.map(|l| 4 + 4 * l as u64).sum();
    4 + body + 8
}

/// Serialize a parameter set (list of named tensors' raw values) — the model
/// update payload of every FL round.
pub fn encode_params(tensors: &[Vec<f32>]) -> Vec<u8> {
    let total: usize = tensors.iter().map(|t| t.len() * 4 + 4).sum();
    let mut w = Writer::with_capacity(total + 16);
    w.u32(tensors.len() as u32);
    for t in tensors {
        w.f32s(t);
    }
    w.finish()
}

pub fn decode_params(bytes: &[u8]) -> Result<Vec<Vec<f32>>, WireError> {
    let mut r = Reader::open(bytes)?;
    let n = r.u32()? as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(r.f32s()?);
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Upload codecs (`federation.compression`) — see the module docs and
// docs/WIRE_FORMAT.md for the byte layouts.
// ---------------------------------------------------------------------------

/// Cap on the value count a codec blob may claim, so a corrupted header can
/// never trigger a multi-gigabyte allocation (mirrors
/// [`crate::transport::tcp::MAX_FRAME_BYTES`]).
pub const MAX_CODEC_VALUES: usize = 1 << 28;

/// Chunk size of the quantizer's per-chunk affine parameters.
pub const QUANT_CHUNK: usize = 256;

const PACK_RAW: u8 = 0;
const PACK_PLANES: u8 = 1;
const PACK_PLANES_RANS: u8 = 2;

/// Precision of the static rANS frequency model: every stream's normalized
/// symbol frequencies sum to exactly `1 << RANS_SCALE_BITS`.
const RANS_SCALE_BITS: u32 = 12;
const RANS_SCALE: u32 = 1 << RANS_SCALE_BITS;
/// Lower bound of the 32-bit rANS state's renormalization interval.
const RANS_L: u32 = 1 << 23;

/// Normalize raw symbol counts to frequencies summing exactly to
/// [`RANS_SCALE`], every present symbol ≥ 1. Deterministic: the fix-up
/// always adjusts the currently-largest entry, so encoder and any
/// re-encoder agree on the table.
fn rans_normalize(counts: &[u32; 256], total: u64) -> Vec<(u8, u32)> {
    let mut freqs: Vec<(u8, u32)> = Vec::new();
    for (s, &c) in counts.iter().enumerate() {
        if c > 0 {
            let f = ((c as u64 * RANS_SCALE as u64) / total).max(1) as u32;
            freqs.push((s as u8, f));
        }
    }
    let mut sum: i64 = freqs.iter().map(|&(_, f)| f as i64).sum();
    while sum > RANS_SCALE as i64 {
        // Shave the currently-largest entry, never below 1. Terminates:
        // at most 256 symbols of frequency 1 sum far below the scale.
        let idx = (0..freqs.len()).max_by_key(|&i| freqs[i].1).unwrap();
        let take = (sum - RANS_SCALE as i64).min(freqs[idx].1 as i64 - 1);
        if take == 0 {
            break;
        }
        freqs[idx].1 -= take as u32;
        sum -= take;
    }
    if sum < RANS_SCALE as i64 {
        let idx = (0..freqs.len()).max_by_key(|&i| freqs[i].1).unwrap();
        freqs[idx].1 += (RANS_SCALE as i64 - sum) as u32;
    }
    freqs
}

/// Entropy-code `data` with a static byte-wise rANS model and append the
/// self-contained stream to `out`: `varint(byte count)`, then (when
/// non-empty) the sparse frequency table (`varint(symbol count)`, then per
/// symbol `u8 symbol, varint(frequency)` in strictly increasing symbol
/// order, frequencies summing to `1 << 12`), then `varint(coded len)` and
/// the coded bytes (4-byte LE final state first, renormalization bytes in
/// decode order). [`rans_decode`] reads it back exactly.
pub fn rans_encode(data: &[u8], out: &mut Vec<u8>) {
    write_varint(out, data.len() as u64);
    if data.is_empty() {
        return;
    }
    let mut counts = [0u32; 256];
    for &b in data {
        counts[b as usize] += 1;
    }
    let freqs = rans_normalize(&counts, data.len() as u64);
    write_varint(out, freqs.len() as u64);
    let mut freq = [0u32; 256];
    let mut cum = [0u32; 256];
    let mut acc = 0u32;
    for &(sym, f) in &freqs {
        out.push(sym);
        write_varint(out, f as u64);
        freq[sym as usize] = f;
        cum[sym as usize] = acc;
        acc += f;
    }
    // Encode in reverse so the decoder reads the stream forward.
    let mut x: u32 = RANS_L;
    let mut tmp: Vec<u8> = Vec::with_capacity(data.len() / 2 + 8);
    for &b in data.iter().rev() {
        let f = freq[b as usize];
        let x_max = ((RANS_L >> RANS_SCALE_BITS) << 8) * f;
        while x >= x_max {
            tmp.push(x as u8);
            x >>= 8;
        }
        x = ((x / f) << RANS_SCALE_BITS) + (x % f) + cum[b as usize];
    }
    write_varint(out, (4 + tmp.len()) as u64);
    out.extend_from_slice(&x.to_le_bytes());
    out.extend(tmp.iter().rev());
}

/// Inverse of [`rans_encode`], consuming one stream from `buf` at `*pos`.
/// `max_len` bounds the allocation: a stream claiming more decoded bytes
/// than the caller's declared plane length is rejected before any buffer is
/// sized from it. Truncated, bit-flipped, or bad-frequency-table streams
/// yield a typed [`WireError`], never a panic — the decoder additionally
/// checks that the state lands back on its initial value with every coded
/// byte consumed.
pub fn rans_decode(buf: &[u8], pos: &mut usize, max_len: usize) -> Result<Vec<u8>, WireError> {
    let n = read_varint(buf, pos)? as usize;
    if n > max_len {
        return Err(WireError::Malformed("rans: declared length exceeds bound"));
    }
    if n == 0 {
        return Ok(Vec::new());
    }
    let k = read_varint(buf, pos)? as usize;
    if k == 0 || k > 256 {
        return Err(WireError::Malformed("rans: bad symbol count"));
    }
    let mut freq = [0u32; 256];
    let mut cum = [0u32; 256];
    let mut slot_sym = [0u8; RANS_SCALE as usize];
    let mut acc: u32 = 0;
    let mut last: i32 = -1;
    for _ in 0..k {
        let sym = *buf.get(*pos).ok_or(WireError::Truncated)?;
        *pos += 1;
        if (sym as i32) <= last {
            return Err(WireError::Malformed("rans: symbols not strictly increasing"));
        }
        last = sym as i32;
        let f = read_varint(buf, pos)?;
        if f == 0 || f > RANS_SCALE as u64 {
            return Err(WireError::Malformed("rans: bad symbol frequency"));
        }
        let f = f as u32;
        if acc + f > RANS_SCALE {
            return Err(WireError::Malformed("rans: frequency table overflows scale"));
        }
        freq[sym as usize] = f;
        cum[sym as usize] = acc;
        for slot in slot_sym.iter_mut().skip(acc as usize).take(f as usize) {
            *slot = sym;
        }
        acc += f;
    }
    if acc != RANS_SCALE {
        return Err(WireError::Malformed("rans: frequency table does not sum to scale"));
    }
    let m = read_varint(buf, pos)? as usize;
    let stream = buf.get(*pos..*pos + m).ok_or(WireError::Truncated)?;
    *pos += m;
    if m < 4 {
        return Err(WireError::Truncated);
    }
    let mut x = u32::from_le_bytes(stream[0..4].try_into().unwrap());
    let mut sp = 4usize;
    let mut out = vec![0u8; n];
    for b in out.iter_mut() {
        let slot = x & (RANS_SCALE - 1);
        let sym = slot_sym[slot as usize];
        *b = sym;
        x = freq[sym as usize] * (x >> RANS_SCALE_BITS) + slot - cum[sym as usize];
        while x < RANS_L {
            let byte = *stream.get(sp).ok_or(WireError::Truncated)?;
            sp += 1;
            x = (x << 8) | byte as u32;
        }
    }
    if sp != m || x != RANS_L {
        return Err(WireError::Malformed("rans: stream does not terminate cleanly"));
    }
    Ok(out)
}

fn write_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

fn read_varint(buf: &[u8], pos: &mut usize) -> Result<u64, WireError> {
    let mut v: u64 = 0;
    let mut shift = 0u32;
    loop {
        let b = *buf.get(*pos).ok_or(WireError::Truncated)?;
        *pos += 1;
        v |= ((b & 0x7F) as u64) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
        if shift >= 64 {
            return Err(WireError::Malformed("varint overflow"));
        }
    }
}

/// Zero-run-length encode one byte plane: alternating `varint(zero run)`,
/// `varint(literal len) + literal bytes` tokens. Short zero runs (< 4 bytes)
/// are folded into literals so pathological alternation can't explode the
/// token count.
fn rle_encode(plane: &[u8]) -> Vec<u8> {
    let n = plane.len();
    let mut out = Vec::with_capacity(n / 4 + 8);
    let mut pos = 0usize;
    while pos < n {
        let zstart = pos;
        while pos < n && plane[pos] == 0 {
            pos += 1;
        }
        write_varint(&mut out, (pos - zstart) as u64);
        if pos >= n {
            break;
        }
        // Literal run: up to the next zero run of >= 4 bytes (or the end).
        let lstart = pos;
        let mut j = pos;
        while j < n {
            if plane[j] == 0 && j + 4 <= n && plane[j..j + 4].iter().all(|&b| b == 0) {
                break;
            }
            j += 1;
        }
        write_varint(&mut out, (j - lstart) as u64);
        out.extend_from_slice(&plane[lstart..j]);
        pos = j;
    }
    out
}

/// Inverse of [`rle_encode`], consuming tokens from `buf` at `*pos` until
/// exactly `n` bytes are emitted.
fn rle_decode(buf: &[u8], pos: &mut usize, n: usize) -> Result<Vec<u8>, WireError> {
    let mut out = vec![0u8; n];
    let mut emitted = 0usize;
    while emitted < n {
        let z = read_varint(buf, pos)? as usize;
        if z > n - emitted {
            return Err(WireError::Malformed("rle zero run overruns plane"));
        }
        emitted += z;
        if emitted == n {
            break;
        }
        let l = read_varint(buf, pos)? as usize;
        if l == 0 {
            return Err(WireError::Malformed("empty rle literal run"));
        }
        if l > n - emitted {
            return Err(WireError::Malformed("rle literal run overruns plane"));
        }
        let src = buf.get(*pos..*pos + l).ok_or(WireError::Truncated)?;
        out[emitted..emitted + l].copy_from_slice(src);
        *pos += l;
        emitted += l;
    }
    Ok(out)
}

/// Losslessly pack `upload` as a delta against `base` (the broadcast the
/// client trained from): XOR the f32 bit patterns, split the delta words
/// into four byte planes, zero-RLE each plane. Falls back to a raw encoding
/// of `upload`'s own bits when the planes don't win (or when `base` has a
/// different length), so the blob is never larger than `4·n + 5` bytes.
/// [`unpack_delta`] with the same `base` reconstructs `upload` **bit for
/// bit** — including negative zero, infinities, and NaN payloads.
///
/// Inputs are bounded by [`MAX_CODEC_VALUES`] to keep the encoder symmetric
/// with its decoder (a larger model could not cross the framed transport
/// anyway — its raw payload would exceed the 1 GiB frame cap).
pub fn pack_delta(upload: &[f32], base: &[f32]) -> Vec<u8> {
    let _sp = crate::trace::span("codec", "pack_delta").arg("values", upload.len());
    pack_delta_impl(upload, base, false)
}

/// The entropy-coded sibling of [`pack_delta`] (`federation.entropy:
/// rans`): the same XOR-delta + byte-plane + zero-RLE pipeline, with each
/// plane's RLE token stream additionally passed through the static rANS
/// coder ([`rans_encode`]) when that wins over the plain RLE bytes. The
/// blob self-describes via its mode byte, so [`unpack_delta`] decodes it
/// with no extra parameter; the raw-fallback size bound (`4·n + 5`) and the
/// bit-exactness guarantee are unchanged.
pub fn pack_delta_rans(upload: &[f32], base: &[f32]) -> Vec<u8> {
    let _sp = crate::trace::span("codec", "pack_delta_rans").arg("values", upload.len());
    pack_delta_impl(upload, base, true)
}

fn pack_delta_impl(upload: &[f32], base: &[f32], entropy: bool) -> Vec<u8> {
    debug_assert!(upload.len() <= MAX_CODEC_VALUES, "upload exceeds the codec value cap");
    let n = upload.len();
    if base.len() == n {
        let mut planes: [Vec<u8>; 4] = std::array::from_fn(|_| Vec::with_capacity(n));
        for (u, b) in upload.iter().zip(base) {
            let x = (u.to_bits() ^ b.to_bits()).to_le_bytes();
            for (plane, byte) in planes.iter_mut().zip(x) {
                plane.push(byte);
            }
        }
        let streams: Vec<Vec<u8>> = planes.iter().map(|p| rle_encode(p)).collect();
        let packed_len: usize = streams.iter().map(|s| s.len()).sum();
        if entropy {
            let mut coded = Vec::with_capacity(packed_len / 2 + 32);
            for s in &streams {
                rans_encode(s, &mut coded);
            }
            if coded.len() < packed_len && coded.len() < 4 * n {
                let mut out = Vec::with_capacity(5 + coded.len());
                out.extend_from_slice(&(n as u32).to_le_bytes());
                out.push(PACK_PLANES_RANS);
                out.extend_from_slice(&coded);
                return out;
            }
        }
        if packed_len < 4 * n {
            let mut out = Vec::with_capacity(5 + packed_len);
            out.extend_from_slice(&(n as u32).to_le_bytes());
            out.push(PACK_PLANES);
            for s in &streams {
                out.extend_from_slice(s);
            }
            return out;
        }
    }
    let mut out = Vec::with_capacity(5 + 4 * n);
    out.extend_from_slice(&(n as u32).to_le_bytes());
    out.push(PACK_RAW);
    for u in upload {
        out.extend_from_slice(&u.to_le_bytes());
    }
    out
}

/// Reassemble f32 values from four decoded byte planes XORed against `base`.
fn planes_to_values(planes: &[Vec<u8>], base: &[f32]) -> Vec<f32> {
    let mut out = Vec::with_capacity(base.len());
    for (i, b) in base.iter().enumerate() {
        let x = u32::from_le_bytes([planes[0][i], planes[1][i], planes[2][i], planes[3][i]]);
        out.push(f32::from_bits(x ^ b.to_bits()));
    }
    out
}

/// Inverse of [`pack_delta`]. `base` must be the same vector the encoder
/// used (the version-stamped broadcast — the coordinator keeps a window of
/// recent broadcasts per version for exactly this lookup). Truncated or
/// malformed blobs yield a typed [`WireError`], never a panic.
pub fn unpack_delta(blob: &[u8], base: &[f32]) -> Result<Vec<f32>, WireError> {
    let _sp = crate::trace::span("codec", "unpack_delta").arg("bytes", blob.len());
    if blob.len() < 5 {
        return Err(WireError::Truncated);
    }
    let n = u32::from_le_bytes(blob[0..4].try_into().unwrap()) as usize;
    if n > MAX_CODEC_VALUES {
        return Err(WireError::Malformed("pack: value count exceeds cap"));
    }
    let mode = blob[4];
    let mut pos = 5usize;
    match mode {
        PACK_RAW => {
            let raw = blob.get(pos..pos + 4 * n).ok_or(WireError::Truncated)?;
            pos += 4 * n;
            if pos != blob.len() {
                return Err(WireError::Malformed("pack: trailing bytes"));
            }
            Ok(raw
                .chunks_exact(4)
                .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
                .collect())
        }
        PACK_PLANES => {
            if base.len() != n {
                return Err(WireError::Malformed("pack: base length mismatch"));
            }
            let mut planes = Vec::with_capacity(4);
            for _ in 0..4 {
                planes.push(rle_decode(blob, &mut pos, n)?);
            }
            if pos != blob.len() {
                return Err(WireError::Malformed("pack: trailing bytes"));
            }
            Ok(planes_to_values(&planes, base))
        }
        PACK_PLANES_RANS => {
            if base.len() != n {
                return Err(WireError::Malformed("pack: base length mismatch"));
            }
            let mut planes = Vec::with_capacity(4);
            for _ in 0..4 {
                // An RLE stream for an n-byte plane never exceeds ~2·n
                // (every literal byte costs ≤ 1 token byte of overhead, zero
                // runs shrink), so the entropy stage's declared length is
                // bounded before any allocation.
                let rle = rans_decode(blob, &mut pos, 2 * n + 16)?;
                let mut rp = 0usize;
                let plane = rle_decode(&rle, &mut rp, n)?;
                if rp != rle.len() {
                    return Err(WireError::Malformed("pack: trailing rle bytes in rans stream"));
                }
                planes.push(plane);
            }
            if pos != blob.len() {
                return Err(WireError::Malformed("pack: trailing bytes"));
            }
            Ok(planes_to_values(&planes, base))
        }
        t => Err(WireError::BadTag(t)),
    }
}

fn pack_codes(out: &mut Vec<u8>, codes: &[u32], bits: u8) {
    if bits == 8 {
        out.extend(codes.iter().map(|&q| q as u8));
    } else {
        for pair in codes.chunks(2) {
            let lo = pair[0] as u8 & 0x0F;
            let hi = if pair.len() > 1 { (pair[1] as u8 & 0x0F) << 4 } else { 0 };
            out.push(lo | hi);
        }
    }
}

/// Affine-quantize an upload delta to `bits`-wide codes (4 or 8; anything
/// else is treated as 8) in [`QUANT_CHUNK`]-value chunks. Returns the wire
/// blob **and** the deterministically dequantized delta — the exact vector
/// [`dequantize_delta`] will reconstruct — so the client can maintain an
/// error-feedback residual (`residual = delta - dequantized`) that agrees
/// bit-for-bit with what the coordinator aggregated. Values are assumed
/// finite (training parameters); non-finite inputs degrade to code 0 of
/// their chunk without panicking. Inputs are bounded by
/// [`MAX_CODEC_VALUES`], mirroring the decoder's cap.
pub fn quantize_delta(delta: &[f32], bits: u8) -> (Vec<u8>, Vec<f32>) {
    debug_assert!(delta.len() <= MAX_CODEC_VALUES, "delta exceeds the codec value cap");
    let _sp = crate::trace::span("codec", "quantize_delta").arg("values", delta.len());
    let bits = if bits == 4 { 4u8 } else { 8u8 };
    let levels = ((1u32 << bits) - 1) as f32;
    let n = delta.len();
    let chunk_overhead = (n / QUANT_CHUNK + 1) * 8;
    let mut blob = Vec::with_capacity(5 + n * bits as usize / 8 + chunk_overhead + 1);
    blob.extend_from_slice(&(n as u32).to_le_bytes());
    blob.push(bits);
    let mut dequant = Vec::with_capacity(n);
    for chunk in delta.chunks(QUANT_CHUNK) {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &v in chunk {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        if !lo.is_finite() {
            lo = 0.0;
        }
        let mut step = if hi > lo { (hi - lo) / levels } else { 0.0 };
        if !step.is_finite() {
            step = 0.0;
        }
        blob.extend_from_slice(&lo.to_le_bytes());
        blob.extend_from_slice(&step.to_le_bytes());
        let mut codes = Vec::with_capacity(chunk.len());
        for &v in chunk {
            let q = if step > 0.0 {
                let q = ((v - lo) / step).round();
                if q.is_finite() {
                    q.clamp(0.0, levels) as u32
                } else {
                    0
                }
            } else {
                0
            };
            codes.push(q);
            dequant.push(lo + step * q as f32);
        }
        pack_codes(&mut blob, &codes, bits);
    }
    (blob, dequant)
}

/// Inverse of [`quantize_delta`]: reconstruct the dequantized delta from a
/// wire blob. Deterministic — `lo + step * code` in f32, the same arithmetic
/// the encoder used for its returned dequantized vector. Truncated or
/// malformed blobs yield a typed [`WireError`], never a panic.
pub fn dequantize_delta(blob: &[u8]) -> Result<Vec<f32>, WireError> {
    let _sp = crate::trace::span("codec", "dequantize_delta").arg("bytes", blob.len());
    if blob.len() < 5 {
        return Err(WireError::Truncated);
    }
    let n = u32::from_le_bytes(blob[0..4].try_into().unwrap()) as usize;
    if n > MAX_CODEC_VALUES {
        return Err(WireError::Malformed("quantized: value count exceeds cap"));
    }
    let bits = blob[4];
    if bits != 4 && bits != 8 {
        return Err(WireError::BadTag(bits));
    }
    let mut pos = 5usize;
    let mut out = Vec::with_capacity(n);
    while out.len() < n {
        let chunk_len = (n - out.len()).min(QUANT_CHUNK);
        let header = blob.get(pos..pos + 8).ok_or(WireError::Truncated)?;
        let lo = f32::from_le_bytes(header[0..4].try_into().unwrap());
        let step = f32::from_le_bytes(header[4..8].try_into().unwrap());
        pos += 8;
        let nbytes = if bits == 8 { chunk_len } else { chunk_len / 2 + chunk_len % 2 };
        let raw = blob.get(pos..pos + nbytes).ok_or(WireError::Truncated)?;
        pos += nbytes;
        if bits == 8 {
            for &q in raw {
                out.push(lo + step * q as f32);
            }
        } else {
            for i in 0..chunk_len {
                let byte = raw[i / 2];
                let q = if i % 2 == 0 { byte & 0x0F } else { byte >> 4 };
                out.push(lo + step * q as f32);
            }
        }
    }
    if pos != blob.len() {
        return Err(WireError::Malformed("quantized: trailing bytes"));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrip() {
        let mut w = Writer::new();
        w.u8(7);
        w.u32(123456);
        w.u64(u64::MAX);
        w.f32(-0.25);
        w.f64(1.0 / 3.0);
        w.str("hello");
        let bytes = w.finish();
        let mut r = Reader::open(&bytes).unwrap();
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 123456);
        assert_eq!(r.u64().unwrap(), u64::MAX);
        assert_eq!(r.f32().unwrap(), -0.25);
        assert_eq!(r.f64().unwrap(), 1.0 / 3.0);
        assert_eq!(r.str().unwrap(), "hello");
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn bulk_roundtrip() {
        let v: Vec<f32> = (0..1000).map(|i| i as f32 * 0.5 - 100.0).collect();
        let q: Vec<i64> = (0..100).map(|i| i * 7 - 350).collect();
        let mut w = Writer::new();
        w.f32s(&v);
        w.i64s(&q);
        let bytes = w.finish();
        let mut r = Reader::open(&bytes).unwrap();
        assert_eq!(r.f32s().unwrap(), v);
        assert_eq!(r.i64s().unwrap(), q);
    }

    #[test]
    fn checksum_detects_corruption() {
        let mut w = Writer::new();
        w.f32s(&[1.0, 2.0, 3.0]);
        let mut bytes = w.finish();
        bytes[5] ^= 0xFF;
        assert!(matches!(Reader::open(&bytes), Err(WireError::BadChecksum)));
    }

    #[test]
    fn truncation_detected() {
        let mut w = Writer::new();
        w.u64(42);
        let bytes = w.finish();
        assert!(Reader::open(&bytes[..4]).is_err());
        // truncated *payload* read
        let mut w = Writer::new();
        w.u32(10); // claims 10 f32s follow but none do
        let bytes = w.finish();
        let mut r = Reader::open(&bytes).unwrap();
        assert!(matches!(r.f32s(), Err(WireError::Truncated)));
    }

    #[test]
    fn pack_roundtrip_is_bitwise_even_for_specials() {
        let base: Vec<f32> = (0..600).map(|i| (i as f32) * 0.25 - 30.0).collect();
        let mut upload: Vec<f32> = base.iter().map(|b| b * 0.99 + 0.001).collect();
        // Bit-pattern specials: the codec must reproduce them exactly.
        upload[0] = -0.0;
        upload[1] = f32::INFINITY;
        upload[2] = f32::NEG_INFINITY;
        upload[3] = f32::from_bits(0x7FC0_1234); // NaN with a payload
        upload[4] = f32::from_bits(1); // subnormal
        let blob = pack_delta(&upload, &base);
        let back = unpack_delta(&blob, &base).unwrap();
        assert_eq!(back.len(), upload.len());
        for (a, b) in upload.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits(), "pack must be bit-exact");
        }
    }

    #[test]
    fn pack_compresses_near_broadcast_uploads() {
        // A realistic shape: the upload is the base plus a small step, so the
        // sign/exponent plane of the XOR delta is almost entirely zeros.
        let base: Vec<f32> = (0..4096).map(|i| ((i % 97) as f32) * 0.01 + 0.5).collect();
        let upload: Vec<f32> = base.iter().map(|b| b + 0.0003).collect();
        let blob = pack_delta(&upload, &base);
        assert!(
            blob.len() < 4 * upload.len(),
            "packed ({}) must beat raw ({})",
            blob.len(),
            4 * upload.len()
        );
        // Identical upload == base degenerates to almost nothing.
        let same = pack_delta(&base, &base);
        assert!(same.len() < 64, "all-zero delta should RLE away, got {}", same.len());
        assert_eq!(unpack_delta(&same, &base).unwrap(), base);
    }

    #[test]
    fn pack_raw_fallback_bounds_the_blob() {
        // Uncorrelated upload/base: planes are noise, the raw fallback kicks
        // in, and the blob stays within header overhead of the raw values.
        let base: Vec<f32> = (0..512u32)
            .map(|i| f32::from_bits(0x9E37_79B9u32.wrapping_mul(i + 1)))
            .collect();
        let upload: Vec<f32> = (0..512u32)
            .map(|i| f32::from_bits(0x85EB_CA6Bu32.wrapping_mul(i + 7)))
            .collect();
        let blob = pack_delta(&upload, &base);
        assert!(blob.len() <= 4 * upload.len() + 5, "blob {} exceeds raw bound", blob.len());
        let back = unpack_delta(&blob, &base).unwrap();
        for (a, b) in upload.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Mismatched base lengths fall back to raw and still roundtrip.
        let blob = pack_delta(&upload, &base[..100]);
        let back = unpack_delta(&blob, &base[..100]);
        assert!(back.is_err() || back.unwrap().len() == upload.len());
    }

    #[test]
    fn pack_rejects_truncation_and_garbage() {
        let base = vec![1.0f32; 300];
        let upload: Vec<f32> = base.iter().map(|b| b + 0.5).collect();
        let blob = pack_delta(&upload, &base);
        for cut in [0, 3, 4, 5, blob.len() / 2, blob.len() - 1] {
            assert!(
                unpack_delta(&blob[..cut], &base).is_err(),
                "cut at {cut} must not decode"
            );
        }
        // Wrong base length for a planes-mode blob is typed, not a panic.
        assert!(matches!(
            unpack_delta(&blob, &base[..10]),
            Err(WireError::Malformed(_))
        ));
        // Unknown mode byte.
        let mut bad = blob.clone();
        bad[4] = 9;
        assert!(matches!(unpack_delta(&bad, &base), Err(WireError::BadTag(9))));
        // Trailing garbage is rejected.
        let mut long = blob.clone();
        long.push(0xAB);
        assert!(unpack_delta(&long, &base).is_err());
    }

    #[test]
    fn rans_roundtrip_identity_on_representative_streams() {
        let cases: Vec<Vec<u8>> = vec![
            vec![],
            vec![0u8; 1],
            vec![0u8; 5000],                                  // all-zero plane
            vec![0xFF; 333],                                  // single non-zero symbol
            (0..=255u8).collect(),                            // uniform alphabet
            (0..10_000u32).map(|i| (i * 2654435761) as u8).collect(), // max-entropy
            (0..4096u32).map(|i| if i % 7 == 0 { (i % 13) as u8 } else { 0 }).collect(),
        ];
        for data in cases {
            let mut blob = Vec::new();
            rans_encode(&data, &mut blob);
            let mut pos = 0usize;
            let back = rans_decode(&blob, &mut pos, data.len()).unwrap();
            assert_eq!(back, data, "rans must be an identity (len {})", data.len());
            assert_eq!(pos, blob.len(), "decode must consume the whole stream");
        }
    }

    #[test]
    fn rans_compresses_skewed_streams() {
        // A zero-dominated stream (the shape RLE token streams take for
        // near-broadcast deltas) must shrink well below its raw length.
        let data: Vec<u8> =
            (0..8192u32).map(|i| if i % 11 == 0 { 1 + (i % 3) as u8 } else { 0 }).collect();
        let mut blob = Vec::new();
        rans_encode(&data, &mut blob);
        assert!(blob.len() < data.len() / 2, "rans {} vs raw {}", blob.len(), data.len());
    }

    #[test]
    fn rans_rejects_truncation_bitflips_and_bad_tables() {
        let data: Vec<u8> = (0..2000u32).map(|i| (i % 17) as u8).collect();
        let mut blob = Vec::new();
        rans_encode(&data, &mut blob);
        // Truncation at every interesting boundary is a typed error.
        for cut in [0, 1, 2, 5, blob.len() / 2, blob.len() - 1] {
            let mut pos = 0usize;
            assert!(
                rans_decode(&blob[..cut], &mut pos, data.len()).is_err(),
                "cut at {cut} must not decode"
            );
        }
        // A declared length beyond the caller's bound is rejected before
        // any allocation is sized from it.
        let mut pos = 0usize;
        assert!(matches!(
            rans_decode(&blob, &mut pos, data.len() - 1),
            Err(WireError::Malformed(_))
        ));
        // Every single-bit flip either decodes to a typed error or to a
        // bounded byte vector — never a panic or oversized allocation.
        for i in 0..blob.len() {
            for bit in [0x01u8, 0x80] {
                let mut bad = blob.clone();
                bad[i] ^= bit;
                let mut pos = 0usize;
                if let Ok(out) = rans_decode(&bad, &mut pos, data.len()) {
                    assert!(out.len() <= data.len());
                }
            }
        }
    }

    #[test]
    fn pack_rans_roundtrips_and_beats_plain_pack_on_skewed_planes() {
        // Near-broadcast upload: sign/exponent planes are almost all zero,
        // and the literal bytes in the low planes are heavily skewed — the
        // entropy stage should win over plain RLE.
        let base: Vec<f32> = (0..4096).map(|i| ((i % 97) as f32) * 0.01 + 0.5).collect();
        let upload: Vec<f32> = base.iter().map(|b| b + 0.0003).collect();
        let plain = pack_delta(&upload, &base);
        let coded = pack_delta_rans(&upload, &base);
        assert!(
            coded.len() <= plain.len(),
            "rans ({}) must not exceed plain pack ({})",
            coded.len(),
            plain.len()
        );
        let back = unpack_delta(&coded, &base).unwrap();
        for (a, b) in upload.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits(), "pack+rans must stay bit-exact");
        }
        // Specials roundtrip through the entropy stage too.
        let mut specials = upload.clone();
        specials[0] = -0.0;
        specials[1] = f32::NEG_INFINITY;
        specials[2] = f32::from_bits(0x7FC0_5678);
        let blob = pack_delta_rans(&specials, &base);
        let back = unpack_delta(&blob, &base).unwrap();
        for (a, b) in specials.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Incompressible inputs keep the raw bound.
        let noise: Vec<f32> = (0..512u32)
            .map(|i| f32::from_bits(0x9E37_79B9u32.wrapping_mul(i + 3)))
            .collect();
        let blob = pack_delta_rans(&noise, &base[..512]);
        assert!(blob.len() <= 4 * noise.len() + 5, "blob {} exceeds raw bound", blob.len());
        for (a, b) in noise.iter().zip(&unpack_delta(&blob, &base[..512]).unwrap()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn pack_rans_rejects_truncation_and_garbage() {
        let base: Vec<f32> = (0..600).map(|i| (i as f32) * 0.25 - 30.0).collect();
        let upload: Vec<f32> = base.iter().map(|b| b * 0.99 + 0.001).collect();
        let blob = pack_delta_rans(&upload, &base);
        assert_eq!(blob[4], 2, "skewed delta should pick the rans mode");
        for cut in [0, 3, 4, 5, blob.len() / 2, blob.len() - 1] {
            assert!(
                unpack_delta(&blob[..cut], &base).is_err(),
                "cut at {cut} must not decode"
            );
        }
        assert!(matches!(unpack_delta(&blob, &base[..10]), Err(WireError::Malformed(_))));
        let mut long = blob.clone();
        long.push(0xAB);
        assert!(unpack_delta(&long, &base).is_err());
    }

    #[test]
    fn quantize_roundtrip_within_step_and_deterministic() {
        for bits in [8u8, 4] {
            let delta: Vec<f32> = (0..1000).map(|i| ((i * 37) % 200) as f32 * 0.01 - 1.0).collect();
            let (blob, dequant) = quantize_delta(&delta, bits);
            let back = dequantize_delta(&blob).unwrap();
            assert_eq!(back.len(), delta.len());
            // The decoder reconstructs exactly what the encoder reported.
            for (a, b) in dequant.iter().zip(&back) {
                assert_eq!(a.to_bits(), b.to_bits(), "dequant must be deterministic");
            }
            // Error bounded by one quantization step per chunk (range / levels).
            let levels = ((1u32 << bits) - 1) as f32;
            for chunk in delta.chunks(QUANT_CHUNK).zip(back.chunks(QUANT_CHUNK)) {
                let (dc, bc) = chunk;
                let lo = dc.iter().copied().fold(f32::INFINITY, f32::min);
                let hi = dc.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                let step = (hi - lo) / levels;
                for (d, r) in dc.iter().zip(bc) {
                    assert!(
                        (d - r).abs() <= step * 0.51 + 1e-6,
                        "bits={bits}: |{d} - {r}| > step {step}"
                    );
                }
            }
            // int4 really is smaller than int8.
            if bits == 4 {
                let (blob8, _) = quantize_delta(&delta, 8);
                assert!(blob.len() < blob8.len());
            }
            // And both are far below the 4-byte/value plaintext encoding.
            assert!(blob.len() < 2 * delta.len());
        }
    }

    #[test]
    fn quantize_handles_degenerate_chunks() {
        // Constant chunk: step 0, every code 0, exact reconstruction.
        let delta = vec![0.75f32; 300];
        let (blob, dequant) = quantize_delta(&delta, 8);
        assert_eq!(dequantize_delta(&blob).unwrap(), dequant);
        assert!(dequant.iter().all(|&v| v == 0.75));
        // Empty delta.
        let (blob, dequant) = quantize_delta(&[], 8);
        assert!(dequant.is_empty());
        assert!(dequantize_delta(&blob).unwrap().is_empty());
        // Odd-length int4 chunk (padding nibble).
        let delta: Vec<f32> = (0..257).map(|i| i as f32 * 0.1).collect();
        let (blob, dequant) = quantize_delta(&delta, 4);
        assert_eq!(dequantize_delta(&blob).unwrap(), dequant);
    }

    #[test]
    fn quantize_rejects_truncation_and_bad_bits() {
        let delta: Vec<f32> = (0..300).map(|i| i as f32 * 0.01).collect();
        let (blob, _) = quantize_delta(&delta, 8);
        for cut in [0, 4, 5, 12, blob.len() - 1] {
            assert!(dequantize_delta(&blob[..cut]).is_err(), "cut at {cut} must not decode");
        }
        let mut bad = blob.clone();
        bad[4] = 7; // 7-bit quantization is not a thing
        assert!(matches!(dequantize_delta(&bad), Err(WireError::BadTag(7))));
        let mut long = blob.clone();
        long.push(0);
        assert!(matches!(dequantize_delta(&long), Err(WireError::Malformed(_))));
    }

    #[test]
    fn params_roundtrip_and_size() {
        let params = vec![vec![1.0f32; 1433 * 64], vec![0.5f32; 64 * 7]];
        let bytes = encode_params(&params);
        // ~4 bytes per value + small overhead
        let payload: usize = params.iter().map(|p| p.len() * 4).sum();
        assert!(bytes.len() >= payload && bytes.len() < payload + 64);
        assert_eq!(bytes.len() as u64, params_wire_len(params.iter().map(|p| p.len())));
        let back = decode_params(&bytes).unwrap();
        assert_eq!(back, params);
    }
}
