//! Wire format for federation traffic.
//!
//! Every payload that crosses the (simulated) network is actually serialized
//! to bytes and parsed back on the receiving side, so (a) the byte counts the
//! monitor reports are real, and (b) serialization cost shows up in measured
//! time exactly as it would in the paper's gRPC/Ray transport. Format:
//! little-endian, length-prefixed sections, FNV-1a checksum trailer.

/// FNV-1a 64-bit checksum.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[derive(Debug)]
pub enum WireError {
    Truncated,
    BadChecksum,
    BadTag(u8),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated => write!(f, "truncated message"),
            WireError::BadChecksum => write!(f, "checksum mismatch"),
            WireError::BadTag(t) => write!(f, "unknown tag {t}"),
        }
    }
}

impl std::error::Error for WireError {}

pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Writer {
        Writer { buf: Vec::new() }
    }

    pub fn with_capacity(cap: usize) -> Writer {
        Writer { buf: Vec::with_capacity(cap) }
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Bulk f32 slice: length prefix + raw LE bytes (single memcpy on LE
    /// targets — this is the hot path for model updates).
    pub fn f32s(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        // SAFETY-free path: f32::to_le_bytes per element would be slow; on
        // little-endian targets the in-memory layout already matches.
        #[cfg(target_endian = "little")]
        {
            let bytes =
                unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) };
            self.buf.extend_from_slice(bytes);
        }
        #[cfg(not(target_endian = "little"))]
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    pub fn i64s(&mut self, v: &[i64]) {
        self.u32(v.len() as u32);
        #[cfg(target_endian = "little")]
        {
            let bytes =
                unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 8) };
            self.buf.extend_from_slice(bytes);
        }
        #[cfg(not(target_endian = "little"))]
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    pub fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Length-prefixed opaque byte blob (nested documents, e.g. an encoded
    /// config inside an `Assign` handshake frame).
    pub fn blob(&mut self, bytes: &[u8]) {
        self.u32(bytes.len() as u32);
        self.buf.extend_from_slice(bytes);
    }

    /// Finalize: append the checksum trailer and return the wire bytes.
    pub fn finish(mut self) -> Vec<u8> {
        let sum = fnv1a(&self.buf);
        self.buf.extend_from_slice(&sum.to_le_bytes());
        self.buf
    }
}

pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    /// Verify the checksum trailer and open a reader over the payload.
    pub fn open(buf: &'a [u8]) -> Result<Reader<'a>, WireError> {
        if buf.len() < 8 {
            return Err(WireError::Truncated);
        }
        let (payload, trailer) = buf.split_at(buf.len() - 8);
        let expect = u64::from_le_bytes(trailer.try_into().unwrap());
        if fnv1a(payload) != expect {
            return Err(WireError::BadChecksum);
        }
        Ok(Reader { buf: payload, pos: 0 })
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.pos + n > self.buf.len() {
            return Err(WireError::Truncated);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32, WireError> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f32s(&mut self) -> Result<Vec<f32>, WireError> {
        let n = self.u32()? as usize;
        let raw = self.take(n * 4)?;
        let mut out = vec![0f32; n];
        #[cfg(target_endian = "little")]
        unsafe {
            std::ptr::copy_nonoverlapping(raw.as_ptr(), out.as_mut_ptr() as *mut u8, n * 4);
        }
        #[cfg(not(target_endian = "little"))]
        for i in 0..n {
            out[i] = f32::from_le_bytes(raw[i * 4..i * 4 + 4].try_into().unwrap());
        }
        Ok(out)
    }

    pub fn i64s(&mut self) -> Result<Vec<i64>, WireError> {
        let n = self.u32()? as usize;
        let raw = self.take(n * 8)?;
        let mut out = vec![0i64; n];
        #[cfg(target_endian = "little")]
        unsafe {
            std::ptr::copy_nonoverlapping(raw.as_ptr(), out.as_mut_ptr() as *mut u8, n * 8);
        }
        #[cfg(not(target_endian = "little"))]
        for i in 0..n {
            out[i] = i64::from_le_bytes(raw[i * 8..i * 8 + 8].try_into().unwrap());
        }
        Ok(out)
    }

    pub fn str(&mut self) -> Result<String, WireError> {
        let n = self.u32()? as usize;
        let raw = self.take(n)?;
        Ok(String::from_utf8_lossy(raw).into_owned())
    }

    pub fn blob(&mut self) -> Result<Vec<u8>, WireError> {
        let n = self.u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

/// Exact wire length of [`encode_params`] for tensors of these lengths,
/// without serializing: count prefix (4) + per tensor (4-byte length prefix
/// + 4 bytes/value) + checksum trailer (8). The federation ledger charges
/// plaintext model uploads at this size — the data-plane payload alone,
/// excluding the update envelope's telemetry fields.
pub fn params_wire_len(tensor_lens: impl Iterator<Item = usize>) -> u64 {
    let body: u64 = tensor_lens.map(|l| 4 + 4 * l as u64).sum();
    4 + body + 8
}

/// Serialize a parameter set (list of named tensors' raw values) — the model
/// update payload of every FL round.
pub fn encode_params(tensors: &[Vec<f32>]) -> Vec<u8> {
    let total: usize = tensors.iter().map(|t| t.len() * 4 + 4).sum();
    let mut w = Writer::with_capacity(total + 16);
    w.u32(tensors.len() as u32);
    for t in tensors {
        w.f32s(t);
    }
    w.finish()
}

pub fn decode_params(bytes: &[u8]) -> Result<Vec<Vec<f32>>, WireError> {
    let mut r = Reader::open(bytes)?;
    let n = r.u32()? as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(r.f32s()?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrip() {
        let mut w = Writer::new();
        w.u8(7);
        w.u32(123456);
        w.u64(u64::MAX);
        w.f32(-0.25);
        w.f64(1.0 / 3.0);
        w.str("hello");
        let bytes = w.finish();
        let mut r = Reader::open(&bytes).unwrap();
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 123456);
        assert_eq!(r.u64().unwrap(), u64::MAX);
        assert_eq!(r.f32().unwrap(), -0.25);
        assert_eq!(r.f64().unwrap(), 1.0 / 3.0);
        assert_eq!(r.str().unwrap(), "hello");
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn bulk_roundtrip() {
        let v: Vec<f32> = (0..1000).map(|i| i as f32 * 0.5 - 100.0).collect();
        let q: Vec<i64> = (0..100).map(|i| i * 7 - 350).collect();
        let mut w = Writer::new();
        w.f32s(&v);
        w.i64s(&q);
        let bytes = w.finish();
        let mut r = Reader::open(&bytes).unwrap();
        assert_eq!(r.f32s().unwrap(), v);
        assert_eq!(r.i64s().unwrap(), q);
    }

    #[test]
    fn checksum_detects_corruption() {
        let mut w = Writer::new();
        w.f32s(&[1.0, 2.0, 3.0]);
        let mut bytes = w.finish();
        bytes[5] ^= 0xFF;
        assert!(matches!(Reader::open(&bytes), Err(WireError::BadChecksum)));
    }

    #[test]
    fn truncation_detected() {
        let mut w = Writer::new();
        w.u64(42);
        let bytes = w.finish();
        assert!(Reader::open(&bytes[..4]).is_err());
        // truncated *payload* read
        let mut w = Writer::new();
        w.u32(10); // claims 10 f32s follow but none do
        let bytes = w.finish();
        let mut r = Reader::open(&bytes).unwrap();
        assert!(matches!(r.f32s(), Err(WireError::Truncated)));
    }

    #[test]
    fn params_roundtrip_and_size() {
        let params = vec![vec![1.0f32; 1433 * 64], vec![0.5f32; 64 * 7]];
        let bytes = encode_params(&params);
        // ~4 bytes per value + small overhead
        let payload: usize = params.iter().map(|p| p.len() * 4).sum();
        assert!(bytes.len() >= payload && bytes.len() < payload + 64);
        assert_eq!(bytes.len() as u64, params_wire_len(params.iter().map(|p| p.len())));
        let back = decode_params(&bytes).unwrap();
        assert_eq!(back, params);
    }
}
