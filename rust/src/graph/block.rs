//! Fixed-shape training blocks and the minibatch sampler.
//!
//! PJRT executables are compiled AOT for static shapes, so every piece of
//! graph data that reaches the XLA train/eval step is first padded into a
//! `Block` with a bucket shape `(n_pad nodes, e_pad arcs, d features)`:
//! - pad *nodes* carry zero features, label 0 and mask 0 (excluded from the
//!   loss and metrics);
//! - pad *arcs* carry weight 0 and point at the last pad node, so the
//!   gather/segment-sum aggregation in the lowered model treats them as
//!   no-ops.
//!
//! The minibatch sampler (paper §3.4 "Minibatch Training for Federated
//! Updates") draws seed nodes and expands bounded-fanout neighborhoods until
//! the bucket is full.

use crate::util::rng::Rng;

use super::csr::Csr;

/// A dense, padded, static-shape batch ready to ship to the runtime.
#[derive(Clone, Debug)]
pub struct Block {
    pub n_pad: usize,
    pub e_pad: usize,
    pub d: usize,
    /// Row-major `[n_pad, d]` node features.
    pub x: Vec<f32>,
    /// Arc endpoints, `[e_pad]` each. Pad arcs point at node `n_pad-1`.
    pub src: Vec<i32>,
    pub dst: Vec<i32>,
    /// Per-arc aggregation coefficient (GCN norm / GIN ones / 0 for pads).
    pub enorm: Vec<f32>,
    /// Node labels `[n_pad]` (0 for pads).
    pub labels: Vec<i32>,
    /// Loss/metric mask `[n_pad]` (1.0 = counted).
    pub mask: Vec<f32>,
    /// How many nodes / arcs are real.
    pub n_real: usize,
    pub e_real: usize,
}

impl Block {
    pub fn empty(n_pad: usize, e_pad: usize, d: usize) -> Block {
        let sink = (n_pad - 1) as i32;
        Block {
            n_pad,
            e_pad,
            d,
            x: vec![0f32; n_pad * d],
            src: vec![sink; e_pad],
            dst: vec![sink; e_pad],
            enorm: vec![0f32; e_pad],
            labels: vec![0i32; n_pad],
            mask: vec![0f32; n_pad],
            n_real: 0,
            e_real: 0,
        }
    }

    /// Payload bytes if this block were shipped over the network (used by the
    /// monitor to account pre-training data exchange for Distributed-GCN).
    pub fn wire_bytes(&self) -> u64 {
        (self.x.len() * 4 + self.src.len() * 4 + self.dst.len() * 4 + self.enorm.len() * 4
            + self.labels.len() * 4
            + self.mask.len() * 4) as u64
    }

    /// Set node `i`'s feature row (must be `d` long).
    pub fn set_feature(&mut self, i: usize, row: &[f32]) {
        assert!(i < self.n_pad && row.len() == self.d);
        self.x[i * self.d..(i + 1) * self.d].copy_from_slice(row);
    }

    /// Add a directed arc with coefficient `w`. Returns false (and ignores
    /// the arc) once the bucket's arc capacity is exhausted.
    pub fn push_arc(&mut self, u: usize, v: usize, w: f32) -> bool {
        if self.e_real >= self.e_pad {
            return false;
        }
        self.src[self.e_real] = u as i32;
        self.dst[self.e_real] = v as i32;
        self.enorm[self.e_real] = w;
        self.e_real += 1;
        true
    }

    /// Number of mask-active nodes.
    pub fn num_masked(&self) -> usize {
        self.mask.iter().filter(|&&m| m > 0.0).count()
    }

    /// Structural invariants (property-tested).
    pub fn validate(&self) -> Result<(), String> {
        if self.x.len() != self.n_pad * self.d {
            return Err("x shape".into());
        }
        if self.src.len() != self.e_pad || self.dst.len() != self.e_pad {
            return Err("arc shape".into());
        }
        if self.n_real > self.n_pad || self.e_real > self.e_pad {
            return Err("real > pad".into());
        }
        for k in 0..self.e_pad {
            let (s, t) = (self.src[k], self.dst[k]);
            if s < 0 || t < 0 || s as usize >= self.n_pad || t as usize >= self.n_pad {
                return Err(format!("arc {k} out of range"));
            }
            if k >= self.e_real && self.enorm[k] != 0.0 {
                return Err(format!("pad arc {k} has nonzero weight"));
            }
        }
        for i in self.n_real..self.n_pad {
            if self.mask[i] != 0.0 {
                return Err(format!("pad node {i} is masked in"));
            }
        }
        Ok(())
    }
}

/// Build a block from an induced subgraph of `csr` over `nodes`
/// (local-id list, deduplicated by the caller). Features/labels/mask are
/// produced by closures over the *position in `nodes`*' id, letting callers
/// map through global ids, aggregated-feature tables, etc.
///
/// GCN symmetric normalization is computed on the induced subgraph (degrees
/// within the block), self-loops included.
pub fn block_from_induced(
    csr: &Csr,
    nodes: &[u32],
    n_pad: usize,
    e_pad: usize,
    d: usize,
    mut feature: impl FnMut(u32, &mut [f32]),
    mut label: impl FnMut(u32) -> i32,
    mut mask: impl FnMut(u32) -> f32,
) -> Block {
    assert!(nodes.len() <= n_pad, "{} nodes exceed bucket {}", nodes.len(), n_pad);
    let mut blk = Block::empty(n_pad, e_pad, d);
    blk.n_real = nodes.len();
    let mut pos = std::collections::HashMap::with_capacity(nodes.len());
    for (i, &u) in nodes.iter().enumerate() {
        pos.insert(u, i);
    }
    // Induced degrees (within the block) for the GCN norm.
    let mut deg = vec![1u32; nodes.len()]; // +1 for the self loop
    for (i, &u) in nodes.iter().enumerate() {
        for &v in csr.neighbors(u) {
            if pos.contains_key(&v) {
                deg[i] += 1;
            }
        }
    }
    let dn: Vec<f32> = deg.iter().map(|&dg| 1.0 / (dg as f32).sqrt()).collect();
    // Self loops first (always fit if e_pad >= n_pad).
    for (i, _) in nodes.iter().enumerate() {
        blk.push_arc(i, i, dn[i] * dn[i]);
    }
    for (i, &u) in nodes.iter().enumerate() {
        for &v in csr.neighbors(u) {
            if let Some(&j) = pos.get(&v) {
                blk.push_arc(j, i, dn[i] * dn[j]); // aggregate src=j into dst=i
            }
        }
    }
    let mut rowbuf = vec![0f32; d];
    for (i, &u) in nodes.iter().enumerate() {
        feature(u, &mut rowbuf);
        blk.set_feature(i, &rowbuf);
        blk.labels[i] = label(u);
        blk.mask[i] = mask(u);
    }
    blk
}

/// Neighbor-sampled node set: start from `seeds`, expand `hops` levels with
/// at most `fanout` sampled neighbors per node, stop at `max_nodes`. Returns
/// the union (seeds first, then discovered nodes, insertion order).
pub fn sample_neighborhood(
    csr: &Csr,
    seeds: &[u32],
    hops: usize,
    fanout: usize,
    max_nodes: usize,
    rng: &mut Rng,
) -> Vec<u32> {
    let mut seen: std::collections::HashSet<u32> = seeds.iter().copied().collect();
    let mut order: Vec<u32> = seeds.to_vec();
    let mut frontier: Vec<u32> = seeds.to_vec();
    for _ in 0..hops {
        let mut next = Vec::new();
        for &u in &frontier {
            let nbrs = csr.neighbors(u);
            let take = fanout.min(nbrs.len());
            let picks: Vec<usize> = if take == nbrs.len() {
                (0..take).collect()
            } else {
                rng.sample_distinct(nbrs.len(), take)
            };
            for p in picks {
                let v = nbrs[p];
                if order.len() >= max_nodes {
                    return order;
                }
                if seen.insert(v) {
                    order.push(v);
                    next.push(v);
                }
            }
        }
        frontier = next;
        if frontier.is_empty() {
            break;
        }
    }
    order
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path4() -> Csr {
        Csr::from_edges(4, &[(0, 1), (1, 2), (2, 3)])
    }

    #[test]
    fn empty_block_is_valid() {
        let b = Block::empty(8, 16, 3);
        b.validate().unwrap();
        assert_eq!(b.num_masked(), 0);
        assert_eq!(b.wire_bytes(), (8 * 3 + 16 * 3 + 8 * 2) as u64 * 4);
    }

    #[test]
    fn induced_block_structure() {
        let g = path4();
        let nodes = [1u32, 2];
        let b = block_from_induced(
            &g,
            &nodes,
            4,
            16,
            2,
            |u, row| {
                row[0] = u as f32;
                row[1] = 1.0;
            },
            |u| u as i32,
            |_| 1.0,
        );
        b.validate().unwrap();
        assert_eq!(b.n_real, 2);
        // arcs: 2 self loops + edge (1,2) both directions
        assert_eq!(b.e_real, 4);
        // induced degree of both = 1 neighbor + self = 2 -> self coeff 1/2
        assert!((b.enorm[0] - 0.5).abs() < 1e-6);
        assert_eq!(b.labels[0], 1);
        assert_eq!(b.x[0], 1.0); // feature(1)[0]
        assert_eq!(b.mask[2], 0.0); // pad
    }

    #[test]
    fn arc_capacity_respected() {
        let g = path4();
        let nodes = [0u32, 1, 2, 3];
        // only 5 arc slots for 4 self loops + 6 arcs -> truncates
        let b = block_from_induced(&g, &nodes, 4, 5, 1, |_, r| r[0] = 0.0, |_| 0, |_| 0.0);
        assert_eq!(b.e_real, 5);
        b.validate().unwrap();
    }

    #[test]
    fn sampler_bounds() {
        let g = path4();
        let mut rng = Rng::seeded(1);
        let ns = sample_neighborhood(&g, &[0], 3, 2, 10, &mut rng);
        assert_eq!(ns[0], 0);
        assert_eq!(ns.len(), 4); // whole path reachable
        let ns = sample_neighborhood(&g, &[0], 3, 2, 2, &mut rng);
        assert_eq!(ns.len(), 2); // capped
        // distinct
        let set: std::collections::HashSet<_> = ns.iter().collect();
        assert_eq!(set.len(), ns.len());
    }

    #[test]
    fn sampler_fanout_limits_expansion() {
        // star: center 0 with 10 leaves
        let edges: Vec<(u32, u32)> = (1..=10).map(|v| (0u32, v as u32)).collect();
        let g = Csr::from_edges(11, &edges);
        let mut rng = Rng::seeded(2);
        let ns = sample_neighborhood(&g, &[0], 1, 3, 100, &mut rng);
        assert_eq!(ns.len(), 4); // seed + 3 sampled leaves
    }
}
