//! Synthetic graph generators.
//!
//! The paper benchmarks on open datasets (Planetoid, OGB, TU, Foursquare)
//! that are not available in this offline environment, so every dataset is
//! replaced by a generator matched to the published statistics (node / edge /
//! feature / class counts, homophily, degree skew). System metrics depend on
//! sizes and shapes — which we match exactly — while accuracy *trends*
//! (aggregation helps under homophily; non-IID hurts) are preserved by the
//! planted-partition construction. See DESIGN.md §0.
//!
//! Two constructions:
//! - [`planted_graph`]: materialized label-homophilous graph with zipf-ish
//!   degrees — used for cora/citeseer/pubmed/arxiv-sim and the LP sets.
//! - [`LazyGraph`]: a *deterministic, storage-free* graph whose adjacency,
//!   labels and features are pure hash functions of the node id — this is how
//!   papers100m-sim reaches 10^8 nodes without 50 GB of RAM.

use std::cell::Cell;

use crate::util::rng::{domains, hash_f32, hash_u64, CounterRng, Rng};

use super::csr::Csr;

thread_local! {
    /// Per-thread generation-work counter: every *heavy* generation draw —
    /// edge-stub targets, feature noise values, GC graph cells, LP region
    /// draws — bumps it, in **both** dataset laws. Under v1 the sequential
    /// generators note their full-dataset work (every build pays all of it,
    /// slice or not); under v2 only the touched entities' keyed draws are
    /// noted, so sliced-build proportionality is asserted against this
    /// counter, not wall clock — a sliced v2 build's generation work must
    /// scale with its assigned nodes, and the fig15 v1-vs-v2 column reads
    /// the same counter for both formats. Cheap O(1)-per-node *bookkeeping*
    /// draws (client assignment, split tags, degree bounds) are deliberately
    /// excluded: they are the partition bookkeeping every build pays,
    /// exactly as PR-5 slicing already allowed. Session builds run on one
    /// thread, so tests and benches bracket a build with
    /// [`gen_work_reset`] + [`gen_work`] on that thread.
    static GEN_WORK: Cell<u64> = Cell::new(0);
}

/// Add `n` units of keyed generation work to this thread's counter.
#[inline]
pub fn gen_work_note(n: u64) {
    GEN_WORK.with(|c| c.set(c.get().wrapping_add(n)));
}

/// Read this thread's generation-work counter.
pub fn gen_work() -> u64 {
    GEN_WORK.with(|c| c.get())
}

/// Reset this thread's generation-work counter.
pub fn gen_work_reset() {
    GEN_WORK.with(|c| c.set(0));
}

/// Parameters of a planted-partition (label-homophilous) graph.
#[derive(Clone, Debug)]
pub struct PlantedSpec {
    pub n: usize,
    pub num_classes: usize,
    /// Average undirected degree.
    pub mean_degree: f64,
    /// Probability that an edge endpoint is drawn from the same class
    /// (label homophily; citation networks sit around 0.7–0.85).
    pub homophily: f64,
    /// Zipf exponent for the degree distribution (2.1–3.0 typical).
    pub degree_skew: f64,
}

/// Generate a labeled homophilous graph. Labels are assigned uniformly at
/// random; each node draws a target degree from a truncated zipf scaled to
/// `mean_degree`, then connects to uniform nodes of the same class with
/// probability `homophily` (otherwise any node).
pub fn planted_graph(spec: &PlantedSpec, rng: &mut Rng) -> (Csr, Vec<u16>) {
    let n = spec.n;
    let labels: Vec<u16> = (0..n).map(|_| rng.below(spec.num_classes) as u16).collect();
    // Bucket nodes by class for homophilous endpoint sampling.
    let mut by_class: Vec<Vec<u32>> = vec![Vec::new(); spec.num_classes];
    for (u, &c) in labels.iter().enumerate() {
        by_class[c as usize].push(u as u32);
    }
    // Degree targets: zipf draw in [1, 100], rescaled to hit mean_degree.
    let raw: Vec<f64> = (0..n).map(|_| 1.0 + rng.zipf(100, spec.degree_skew) as f64).collect();
    let raw_mean = raw.iter().sum::<f64>() / n as f64;
    let scale = spec.mean_degree / raw_mean;
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity((n as f64 * spec.mean_degree / 2.0) as usize);
    let mut stub_draws = 0u64;
    for u in 0..n {
        // Each node *initiates* half its target degree; the other half comes
        // from being selected as an endpoint.
        let k = ((raw[u] * scale / 2.0).round() as usize).max(1);
        stub_draws += k as u64;
        for _ in 0..k {
            let v = if rng.chance(spec.homophily) {
                let bucket = &by_class[labels[u] as usize];
                bucket[rng.below(bucket.len())]
            } else {
                rng.below(n) as u32
            };
            if v as usize != u {
                edges.push((u as u32, v));
            }
        }
    }
    // v1 pays full-graph generation on every build, slice or not — the
    // counter makes that visible next to v2's O(assigned) numbers.
    gen_work_note(stub_draws);
    (Csr::from_edges(n, &edges), labels)
}

/// Class-conditioned dense features: feature = signal ⋅ prototype(label) +
/// noise. Prototypes are sparse random ±1 patterns so that high-dimensional
/// datasets (cora-sim d=1433) behave like bag-of-words. `signal` controls
/// task difficulty; aggregation over homophilous neighborhoods (GCN, FedGCN)
/// denoises, which is exactly the effect the paper's accuracy plots rely on.
pub fn class_features(
    labels: &[u16],
    num_classes: usize,
    d: usize,
    signal: f32,
    rng: &mut Rng,
) -> Vec<f32> {
    // Sparse ±1 prototypes: each class activates d/16 dimensions (min 4).
    let active = (d / 16).max(4).min(d);
    let mut protos = vec![0f32; num_classes * d];
    for c in 0..num_classes {
        let dims = rng.sample_distinct(d, active);
        for &j in &dims {
            protos[c * d + j] = if rng.chance(0.5) { 1.0 } else { -1.0 };
        }
    }
    gen_work_note((labels.len() * d) as u64);
    let mut x = vec![0f32; labels.len() * d];
    for (u, &lab) in labels.iter().enumerate() {
        let p = &protos[lab as usize * d..(lab as usize + 1) * d];
        let row = &mut x[u * d..(u + 1) * d];
        for j in 0..d {
            row[j] = signal * p[j] + rng.normal() as f32;
        }
    }
    x
}

/// Dataset-format **v2** planted graph: the same statistical law as
/// [`planted_graph`] (class-homophilous edges, zipf-ish degrees, sparse ±1
/// class prototypes), but every node's labels, degrees, edge stubs, features
/// and split tag are *keyed* draws from [`CounterRng`] streams — a pure
/// function of `(seed, domain, entity)`. There is no sequential stream, so:
///
/// - any node's row is computable in O(degree) with **no replay and no
///   [`Rng::skip`]**,
/// - a sliced build and a full build produce bitwise-identical values for
///   every entity both materialize, by construction,
/// - generation work is proportional to the entities actually touched
///   (tracked via [`gen_work`]).
///
/// Differences from the v1 law (why v2 is a *dataset format*, not a drop-in):
/// - labels are contiguous equal blocks (class `c` spans
///   `[c·n/k, (c+1)·n/k)`) instead of iid uniform draws, so homophilous
///   endpoint sampling is an O(1) range draw instead of an O(n) bucket;
/// - the degree scale uses the *analytic* truncated-zipf mean instead of the
///   empirical mean of all n draws (removing the one O(n) coupling);
/// - adjacency is the union of per-node out-stubs (the [`LazyGraph`]
///   stance: a client's view is its own nodes' stub rows), symmetrized
///   inside each materialized view.
#[derive(Clone, Debug)]
pub struct KeyedPlanted {
    pub spec: PlantedSpec,
    pub seed: u64,
    /// Degree rescale: `mean_degree / E[raw]` with `E[raw]` the analytic
    /// mean of the truncated zipf in `[1, 100]`.
    scale: f64,
}

impl KeyedPlanted {
    pub fn new(spec: PlantedSpec, seed: u64) -> KeyedPlanted {
        assert!(spec.n >= spec.num_classes && spec.num_classes >= 1);
        let (mut num, mut den) = (0f64, 0f64);
        for k in 1..=100u32 {
            let w = (k as f64).powf(-spec.degree_skew);
            num += k as f64 * w;
            den += w;
        }
        let scale = spec.mean_degree / (num / den);
        KeyedPlanted { spec, seed, scale }
    }

    /// Label of node `u` — contiguous equal class blocks, no RNG.
    #[inline]
    pub fn label(&self, u: usize) -> u16 {
        debug_assert!(u < self.spec.n);
        ((u as u128 * self.spec.num_classes as u128) / self.spec.n as u128) as u16
    }

    /// The node-id range `[lo, hi)` of class `c`.
    #[inline]
    pub fn class_range(&self, c: usize) -> (usize, usize) {
        let k = self.spec.num_classes;
        (c * self.spec.n / k, (c + 1) * self.spec.n / k)
    }

    /// Number of out-stubs node `u` initiates (half its target degree, as in
    /// v1: the other half arrives as other nodes' stubs). One cheap keyed
    /// zipf draw; not counted as generation work (degree *bounds* are
    /// partition bookkeeping).
    pub fn stub_count(&self, u: usize) -> usize {
        let raw = 1.0 + CounterRng::at(self.seed, domains::DEGREE, u as u64)
            .zipf(100, self.spec.degree_skew) as f64;
        ((raw * self.scale / 2.0).round() as usize).max(1)
    }

    /// The out-stub targets of node `u` (self-stubs skipped, duplicates
    /// kept — materialized views dedup on CSR build). Each stub draws from
    /// its own `(u, j)` keyed stream, so the row is slice-independent.
    pub fn stubs(&self, u: usize) -> Vec<u32> {
        let k = self.stub_count(u);
        gen_work_note(k as u64);
        let mut out = Vec::with_capacity(k);
        for j in 0..k {
            let mut r = CounterRng::at2(self.seed, domains::EDGE, u as u64, j as u64);
            let v = if r.chance(self.spec.homophily) {
                let (lo, hi) = self.class_range(self.label(u) as usize);
                lo + r.below(hi - lo)
            } else {
                r.below(self.spec.n)
            };
            if v != u {
                out.push(v as u32);
            }
        }
        out
    }

    /// Sparse ±1 class prototypes, keyed per class (same shape rule as
    /// [`class_features`]: `d/16` active dims, min 4).
    pub fn protos(&self, d: usize) -> Vec<f32> {
        let active = (d / 16).max(4).min(d);
        let mut protos = vec![0f32; self.spec.num_classes * d];
        for c in 0..self.spec.num_classes {
            let mut r = CounterRng::at(self.seed, domains::PROTO, c as u64);
            let dims = r.sample_distinct(d, active);
            for &j in &dims {
                protos[c * d + j] = if r.chance(0.5) { 1.0 } else { -1.0 };
            }
        }
        protos
    }

    /// Write node `u`'s feature row (`signal·prototype(label) + N(0,1)`
    /// noise) into `buf`, from `u`'s own keyed stream.
    pub fn feature_into(&self, u: usize, protos: &[f32], signal: f32, buf: &mut [f32]) {
        let d = buf.len();
        gen_work_note(d as u64);
        let p = &protos[self.label(u) as usize * d..(self.label(u) as usize + 1) * d];
        let mut r = CounterRng::at(self.seed, domains::FEATURE, u as u64);
        for (j, b) in buf.iter_mut().enumerate() {
            *b = signal * p[j] + r.normal() as f32;
        }
    }

    /// A uniform `[0,1)` split tag for node `u` (train/val/test thresholds
    /// are the caller's). Cheap bookkeeping draw, not generation work.
    #[inline]
    pub fn split_tag(&self, u: usize) -> f64 {
        CounterRng::at(self.seed, domains::SPLIT, u as u64).f64()
    }

    /// Materialize the full stub-union graph as a symmetric [`Csr`] — test
    /// and small-scale support; sliced builds never call this.
    pub fn to_csr(&self) -> Csr {
        let mut edges = Vec::new();
        for u in 0..self.spec.n {
            for v in self.stubs(u) {
                edges.push((u as u32, v));
            }
        }
        Csr::from_edges(self.spec.n, &edges)
    }
}

/// Deterministic, storage-free graph for papers100m-sim.
///
/// Node ids are grouped into contiguous *communities* whose sizes follow a
/// power law (country-population style). A node's adjacency row is a pure
/// function of `(seed, u)`: `deg(u)` hash-drawn in [min_deg, max_deg], each
/// stub goes to a uniform node of the same community with probability
/// `homophily`, else to a uniform global node. Labels and features are also
/// hash-derived, with the label signal planted in the features so learning
/// is possible.
///
/// Note: adjacency is a union of *out-stubs*; a client materializing its
/// local subgraph sees its own rows (its nodes' stubs), matching the
/// federated setting where each client knows the edges incident to its own
/// data. Cross-client stubs are exactly the paper's "cross-client edges".
#[derive(Clone, Debug)]
pub struct LazyGraph {
    pub seed: u64,
    pub n: u64,
    pub num_classes: usize,
    pub feat_dim: usize,
    pub min_deg: u32,
    pub max_deg: u32,
    pub homophily: f32,
    /// Community boundaries: community i spans [bounds[i], bounds[i+1]).
    bounds: Vec<u64>,
    /// Feature signal strength.
    pub signal: f32,
}

impl LazyGraph {
    pub fn new(
        seed: u64,
        n: u64,
        num_communities: usize,
        num_classes: usize,
        feat_dim: usize,
        mean_deg: u32,
        homophily: f32,
        signal: f32,
    ) -> LazyGraph {
        assert!(num_communities >= 1 && n >= num_communities as u64);
        // Power-law community sizes: w_i ∝ (i+1)^{-0.8}, then scaled to n.
        let weights: Vec<f64> = (0..num_communities).map(|i| ((i + 1) as f64).powf(-0.8)).collect();
        let total: f64 = weights.iter().sum();
        let mut bounds = Vec::with_capacity(num_communities + 1);
        bounds.push(0u64);
        let mut acc = 0f64;
        for (i, w) in weights.iter().enumerate() {
            acc += w;
            let b = if i + 1 == num_communities { n } else { ((acc / total) * n as f64) as u64 };
            // Ensure strictly increasing (at least one node per community).
            let prev = *bounds.last().unwrap();
            bounds.push(b.max(prev + 1).min(n));
        }
        *bounds.last_mut().unwrap() = n;
        LazyGraph {
            seed,
            n,
            num_classes,
            feat_dim,
            min_deg: (mean_deg / 2).max(1),
            max_deg: mean_deg * 3 / 2 + 1,
            homophily,
            bounds,
            signal,
        }
    }

    pub fn num_communities(&self) -> usize {
        self.bounds.len() - 1
    }

    /// Which community does node `u` belong to (binary search on bounds).
    pub fn community(&self, u: u64) -> usize {
        debug_assert!(u < self.n);
        match self.bounds.binary_search(&u) {
            Ok(i) => {
                // u is a left boundary: it's the start of community i, except
                // when duplicate bounds collapse; walk forward to the span.
                let mut i = i;
                while i + 1 < self.bounds.len() && self.bounds[i + 1] <= u {
                    i += 1;
                }
                i.min(self.num_communities() - 1)
            }
            Err(i) => i - 1,
        }
    }

    pub fn community_range(&self, c: usize) -> (u64, u64) {
        (self.bounds[c], self.bounds[c + 1])
    }

    #[inline]
    pub fn degree(&self, u: u64) -> u32 {
        let span = self.max_deg - self.min_deg + 1;
        self.min_deg + (hash_u64(self.seed ^ 0xDE6, u, 0) % span as u64) as u32
    }

    /// The deterministic out-stub list of `u` (self-stubs skipped).
    pub fn neighbors(&self, u: u64) -> Vec<u64> {
        let deg = self.degree(u);
        let c = self.community(u);
        let (lo, hi) = self.community_range(c);
        let span = hi - lo;
        let mut out = Vec::with_capacity(deg as usize);
        for j in 0..deg {
            let h = hash_u64(self.seed ^ 0xAD30, u, j as u64);
            let same = (h & 0xFFFF) as f32 / 65536.0 < self.homophily;
            let v = if same && span > 1 {
                lo + (h >> 16) % span
            } else {
                (h >> 16) % self.n
            };
            if v != u {
                out.push(v);
            }
        }
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Hash-derived label: community-correlated (communities lean towards a
    /// majority class) with 25% noise — gives GNNs structure to exploit.
    pub fn label(&self, u: u64) -> u16 {
        let c = self.community(u);
        let majority = (hash_u64(self.seed ^ 0x1AB5, c as u64, 0) % self.num_classes as u64) as u16;
        if hash_f32(self.seed ^ 0x1AB6, u, 1) < 0.75 {
            majority
        } else {
            (hash_u64(self.seed ^ 0x1AB7, u, 2) % self.num_classes as u64) as u16
        }
    }

    /// Write node `u`'s feature row into `buf` (len = feat_dim): sparse ±1
    /// class prototype (hash-derived) scaled by `signal` + N(0,1)-ish hash
    /// noise. No storage: 100M nodes cost nothing until sampled.
    pub fn feature_into(&self, u: u64, buf: &mut [f32]) {
        assert_eq!(buf.len(), self.feat_dim);
        gen_work_note(self.feat_dim as u64);
        let lab = self.label(u) as u64;
        let active = (self.feat_dim / 16).max(4);
        for (j, b) in buf.iter_mut().enumerate() {
            // Approximate N(0,1) via sum of 4 uniforms (Irwin–Hall, shifted).
            let s = hash_f32(self.seed ^ 0xFEA7, u, j as u64)
                + hash_f32(self.seed ^ 0xFEA8, u, j as u64)
                + hash_f32(self.seed ^ 0xFEA9, u, j as u64)
                + hash_f32(self.seed ^ 0xFEAA, u, j as u64);
            *b = (s - 2.0) * 1.732; // var(IH4)=4/12 -> scale to ~unit variance
        }
        // Plant the class prototype on `active` hash-chosen dims.
        for a in 0..active {
            let j = (hash_u64(self.seed ^ 0x9027, lab, a as u64) % self.feat_dim as u64) as usize;
            let sign = if hash_u64(self.seed ^ 0x9028, lab, a as u64) & 1 == 0 { 1.0 } else { -1.0 };
            buf[j] += self.signal * sign;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> PlantedSpec {
        PlantedSpec { n: 500, num_classes: 7, mean_degree: 4.0, homophily: 0.8, degree_skew: 2.5 }
    }

    #[test]
    fn planted_graph_stats() {
        let mut rng = Rng::seeded(1);
        let (g, labels) = planted_graph(&spec(), &mut rng);
        g.validate().unwrap();
        assert_eq!(labels.len(), 500);
        let mean_deg = g.num_arcs() as f64 / g.n as f64;
        assert!((2.0..8.0).contains(&mean_deg), "mean degree {mean_deg}");
        // Homophily: most edges connect same-label endpoints.
        let same = g.edges().filter(|&(u, v)| labels[u as usize] == labels[v as usize]).count();
        let frac = same as f64 / g.num_edges() as f64;
        assert!(frac > 0.6, "homophily too low: {frac}");
    }

    #[test]
    fn planted_graph_deterministic() {
        let (g1, l1) = planted_graph(&spec(), &mut Rng::seeded(9));
        let (g2, l2) = planted_graph(&spec(), &mut Rng::seeded(9));
        assert_eq!(l1, l2);
        assert_eq!(g1.adj, g2.adj);
    }

    #[test]
    fn class_features_separate_classes() {
        let mut rng = Rng::seeded(2);
        let labels: Vec<u16> = (0..200).map(|i| (i % 4) as u16).collect();
        let d = 64;
        let x = class_features(&labels, 4, d, 3.0, &mut rng);
        // Mean intra-class cosine similarity should exceed inter-class.
        let cos = |a: &[f32], b: &[f32]| {
            let dot: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
            let na: f32 = a.iter().map(|v| v * v).sum::<f32>().sqrt();
            let nb: f32 = b.iter().map(|v| v * v).sum::<f32>().sqrt();
            dot / (na * nb)
        };
        let row = |i: usize| &x[i * d..(i + 1) * d];
        let intra = cos(row(0), row(4)); // both class 0
        let inter = cos(row(0), row(1)); // class 0 vs 1
        assert!(intra > inter, "intra {intra} <= inter {inter}");
    }

    fn lazy() -> LazyGraph {
        LazyGraph::new(7, 100_000, 50, 8, 32, 6, 0.7, 3.0)
    }

    #[test]
    fn lazy_graph_community_lookup() {
        let g = lazy();
        assert_eq!(g.num_communities(), 50);
        for c in 0..g.num_communities() {
            let (lo, hi) = g.community_range(c);
            assert!(lo < hi);
            assert_eq!(g.community(lo), c);
            assert_eq!(g.community(hi - 1), c);
        }
        // Power-law: first community much larger than last.
        let (l0, h0) = g.community_range(0);
        let (ll, hl) = g.community_range(49);
        assert!(h0 - l0 > (hl - ll) * 3);
    }

    #[test]
    fn lazy_graph_deterministic_and_bounded() {
        let g = lazy();
        for u in [0u64, 1, 99_999, 31_337] {
            let n1 = g.neighbors(u);
            let n2 = g.neighbors(u);
            assert_eq!(n1, n2);
            assert!(n1.iter().all(|&v| v < g.n && v != u));
            assert!(n1.len() <= g.max_deg as usize);
        }
    }

    #[test]
    fn lazy_labels_community_correlated() {
        let g = lazy();
        // Within one community, the majority label should dominate.
        let (lo, hi) = g.community_range(3);
        let mut counts = vec![0usize; g.num_classes];
        for u in lo..hi.min(lo + 2000) {
            counts[g.label(u) as usize] += 1;
        }
        let max = *counts.iter().max().unwrap();
        let total: usize = counts.iter().sum();
        assert!(max as f64 / total as f64 > 0.5);
    }

    #[test]
    fn lazy_features_shape_and_determinism() {
        let g = lazy();
        let mut a = vec![0f32; 32];
        let mut b = vec![0f32; 32];
        g.feature_into(123, &mut a);
        g.feature_into(123, &mut b);
        assert_eq!(a, b);
        assert!(a.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn keyed_planted_matches_v1_statistics() {
        // v2's stub-union graph must land on the same statistical law the
        // v1 sequential generator produces: mean degree near the spec,
        // strong label homophily, balanced classes.
        let kp = KeyedPlanted::new(spec(), 77);
        let g = kp.to_csr();
        g.validate().unwrap();
        let mean_deg = g.num_arcs() as f64 / g.n as f64;
        assert!((2.0..8.0).contains(&mean_deg), "v2 mean degree {mean_deg}");
        let same = g
            .edges()
            .filter(|&(u, v)| kp.label(u as usize) == kp.label(v as usize))
            .count();
        let frac = same as f64 / g.num_edges() as f64;
        assert!(frac > 0.6, "v2 homophily too low: {frac}");
        // Compare against a v1 draw of the same spec: the two mean degrees
        // agree within a loose band (different stream, same law).
        let (g1, _) = planted_graph(&spec(), &mut Rng::seeded(77));
        let v1_mean = g1.num_arcs() as f64 / g1.n as f64;
        assert!(
            (mean_deg - v1_mean).abs() < 2.0,
            "v2 mean degree {mean_deg} vs v1 {v1_mean}"
        );
        // Class blocks are balanced.
        let mut counts = vec![0usize; 7];
        for u in 0..500 {
            counts[kp.label(u) as usize] += 1;
        }
        assert!(counts.iter().all(|&c| (65..=80).contains(&c)), "{counts:?}");
    }

    #[test]
    fn keyed_planted_features_match_v1_moments() {
        let kp = KeyedPlanted::new(spec(), 31);
        let d = 64;
        let protos = kp.protos(d);
        let mut buf = vec![0f32; d];
        let (mut sum, mut sq, mut n) = (0f64, 0f64, 0usize);
        for u in (0..500).step_by(3) {
            kp.feature_into(u, &protos, 0.0, &mut buf);
            for &x in &buf {
                sum += x as f64;
                sq += (x as f64) * (x as f64);
                n += 1;
            }
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        // signal=0 leaves pure N(0,1) noise: match v1's moments.
        assert!(mean.abs() < 0.05, "feature mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "feature var {var}");
    }

    #[test]
    fn keyed_planted_rows_are_slice_independent() {
        // Bitwise: a row computed in isolation equals the row computed
        // after (or interleaved with) any other rows — there is no stream.
        let kp = KeyedPlanted::new(spec(), 5);
        let protos = kp.protos(32);
        let mut a = vec![0f32; 32];
        let mut b = vec![0f32; 32];
        for &u in &[0usize, 17, 499] {
            let alone = kp.stubs(u);
            for w in 0..500 {
                let _ = kp.stub_count(w);
            }
            let after = kp.stubs(u);
            assert_eq!(alone, after);
            kp.feature_into(u, &protos, 1.0, &mut a);
            kp.feature_into(u, &protos, 1.0, &mut b);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn gen_work_counts_keyed_draws() {
        let kp = KeyedPlanted::new(spec(), 13);
        gen_work_reset();
        let base = gen_work();
        let k = kp.stub_count(42) as u64;
        let _ = kp.stubs(42);
        assert_eq!(gen_work() - base, k);
        let protos = kp.protos(32);
        let before = gen_work();
        let mut buf = vec![0f32; 32];
        kp.feature_into(42, &protos, 1.0, &mut buf);
        assert_eq!(gen_work() - before, 32);
    }
}
