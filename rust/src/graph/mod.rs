//! Graph substrate: CSR storage, synthetic generators, client partitioners,
//! per-client local views with cross-client edges, and fixed-shape training
//! blocks for the AOT runtime.

pub mod block;
pub mod csr;
pub mod generate;
pub mod partition;
pub mod subgraph;

pub use block::{block_from_induced, sample_neighborhood, Block};
pub use csr::Csr;
pub use generate::{
    class_features, gen_work, gen_work_note, gen_work_reset, planted_graph, KeyedPlanted,
    LazyGraph, PlantedSpec,
};
pub use partition::{
    dirichlet_partition, group_partition, keyed_assign_of, keyed_dirichlet_partition,
    keyed_dirichlet_props, label_skew, powerlaw_partition, random_partition, Partition,
};
pub use subgraph::{
    build_local_graph, build_local_graph_keyed, build_local_graphs, halo_count,
    local_neighbor_contribution, neighbor_feature_sums, LocalGraph,
};
