//! Client partitioners.
//!
//! The paper partitions nodes across trainers three ways:
//! - **Dirichlet label skew** with concentration β (`iid_beta` in its
//!   configs; β=10000 ≈ IID, small β = heavy non-IID) — used for the NC
//!   benchmarks (Fig 9, Table 2, Fig 15).
//! - **Power-law sizes** mimicking country populations — used for
//!   Ogbn-Papers100M with 195 clients (Fig 12).
//! - **Region partition** — the LP task gives each client one country's
//!   check-in data (Fig 10).

use crate::util::rng::{domains, CounterRng, Rng};

/// A node→client assignment plus its inverse.
#[derive(Clone, Debug)]
pub struct Partition {
    pub num_clients: usize,
    /// `assign[u]` = owning client of node u.
    pub assign: Vec<u32>,
    /// `members[c]` = sorted node ids owned by client c.
    pub members: Vec<Vec<u32>>,
}

impl Partition {
    pub fn from_assignment(num_clients: usize, assign: Vec<u32>) -> Partition {
        let mut members: Vec<Vec<u32>> = vec![Vec::new(); num_clients];
        for (u, &c) in assign.iter().enumerate() {
            assert!((c as usize) < num_clients, "client id out of range");
            members[c as usize].push(u as u32);
        }
        Partition { num_clients, assign, members }
    }

    /// Invariant check: members ↔ assign are inverse mappings and cover all
    /// nodes exactly once. Used by property tests.
    pub fn validate(&self, n: usize) -> Result<(), String> {
        if self.assign.len() != n {
            return Err("assign length mismatch".into());
        }
        let total: usize = self.members.iter().map(|m| m.len()).sum();
        if total != n {
            return Err(format!("members cover {total} != {n} nodes"));
        }
        for (c, m) in self.members.iter().enumerate() {
            for w in m.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("members[{c}] not sorted/unique"));
                }
            }
            for &u in m {
                if self.assign[u as usize] as usize != c {
                    return Err(format!("assign/members disagree at node {u}"));
                }
            }
        }
        Ok(())
    }

    pub fn sizes(&self) -> Vec<usize> {
        self.members.iter().map(|m| m.len()).collect()
    }
}

/// Uniform random assignment (baseline; also β→∞ limit).
pub fn random_partition(n: usize, num_clients: usize, rng: &mut Rng) -> Partition {
    let assign: Vec<u32> = (0..n).map(|_| rng.below(num_clients) as u32).collect();
    Partition::from_assignment(num_clients, assign)
}

/// Dirichlet label-skew partition: for each class, split its nodes across
/// clients with proportions ~ Dir(β). β=10000 reproduces the paper's "IID"
/// setting; β≤1 is strongly non-IID.
pub fn dirichlet_partition(
    labels: &[u16],
    num_classes: usize,
    num_clients: usize,
    beta: f64,
    rng: &mut Rng,
) -> Partition {
    let mut assign = vec![0u32; labels.len()];
    for c in 0..num_classes {
        let nodes: Vec<usize> =
            (0..labels.len()).filter(|&u| labels[u] as usize == c).collect();
        if nodes.is_empty() {
            continue;
        }
        let props = rng.dirichlet(beta, num_clients);
        // Convert proportions to contiguous cut points over a shuffled list.
        let mut shuffled = nodes.clone();
        rng.shuffle(&mut shuffled);
        let mut start = 0usize;
        let mut acc = 0f64;
        for (k, &p) in props.iter().enumerate() {
            acc += p;
            let end = if k + 1 == num_clients {
                shuffled.len()
            } else {
                ((acc * shuffled.len() as f64).round() as usize).min(shuffled.len())
            };
            for &u in &shuffled[start..end] {
                assign[u] = k as u32;
            }
            start = end;
        }
    }
    Partition::from_assignment(num_clients, assign)
}

/// Dataset-format **v2** Dirichlet label-skew partition, fully keyed: the
/// per-class client proportions are one [`CounterRng`] draw per class
/// ([`domains::PART_CLASS`]) and every node's client is one categorical
/// draw from its own stream ([`domains::PART_NODE`]) against its label's
/// proportions. No shared stream, no shuffle: `keyed_assign_of(u)` is O(1)
/// given the proportions table, so a sliced build can answer "who owns node
/// v?" for any halo node without touching the rest of the graph — and the
/// O(n) `members` scan is pure bookkeeping (one cheap hash per node, no
/// generation work).
///
/// Statistically this matches the v1 shuffle-and-cut construction: both give
/// each class Dir(β) client proportions; v2 realizes them multinomially
/// instead of by exact cuts (the same law the β knob is quoted for).
pub fn keyed_dirichlet_props(
    seed: u64,
    num_classes: usize,
    num_clients: usize,
    beta: f64,
) -> Vec<Vec<f64>> {
    (0..num_classes)
        .map(|c| CounterRng::at(seed, domains::PART_CLASS, c as u64).dirichlet(beta, num_clients))
        .collect()
}

/// The owning client of node `u` under the keyed v2 partition — a pure
/// function of `(seed, u, label)` given the per-class proportions.
#[inline]
pub fn keyed_assign_of(seed: u64, u: usize, label: u16, props: &[Vec<f64>]) -> u32 {
    CounterRng::at(seed, domains::PART_NODE, u as u64).categorical(&props[label as usize]) as u32
}

/// Materialize the keyed v2 partition for all `n` nodes (the bookkeeping
/// pass every build performs; `labels_of` is the dataset's O(1) label rule).
pub fn keyed_dirichlet_partition(
    seed: u64,
    n: usize,
    num_clients: usize,
    props: &[Vec<f64>],
    labels_of: impl Fn(usize) -> u16,
) -> Partition {
    let assign: Vec<u32> =
        (0..n).map(|u| keyed_assign_of(seed, u, labels_of(u), props)).collect();
    Partition::from_assignment(num_clients, assign)
}

/// Power-law sized partition (country-population style): client k gets a
/// share ∝ (k+1)^{-alpha}; node→client assignment is random given the sizes.
pub fn powerlaw_partition(n: usize, num_clients: usize, alpha: f64, rng: &mut Rng) -> Partition {
    let weights: Vec<f64> = (0..num_clients).map(|k| ((k + 1) as f64).powf(-alpha)).collect();
    let total: f64 = weights.iter().sum();
    // Cut a shuffled node list at the cumulative shares.
    let perm = rng.permutation(n);
    let mut assign = vec![0u32; n];
    let mut start = 0usize;
    let mut acc = 0f64;
    for k in 0..num_clients {
        acc += weights[k] / total;
        let end = if k + 1 == num_clients { n } else { ((acc * n as f64) as usize).min(n) };
        // Guarantee at least one node per client while possible.
        let end = end.max((start + 1).min(n));
        for &u in &perm[start..end.min(perm.len())] {
            assign[u] = k as u32;
        }
        start = end;
    }
    drop(perm);
    Partition::from_assignment(num_clients, assign)
}

/// Partition by a precomputed group id per node (region / country for the
/// LP task: one client per region).
pub fn group_partition(groups: &[u32], num_clients: usize) -> Partition {
    Partition::from_assignment(num_clients, groups.to_vec())
}

/// Label-distribution statistics of a partition — used in tests and in the
/// monitor's data summary (how non-IID did β make the split?).
pub fn label_skew(partition: &Partition, labels: &[u16], num_classes: usize) -> Vec<Vec<f64>> {
    partition
        .members
        .iter()
        .map(|m| {
            let mut counts = vec![0f64; num_classes];
            for &u in m {
                counts[labels[u as usize] as usize] += 1.0;
            }
            let total: f64 = counts.iter().sum::<f64>().max(1.0);
            counts.iter().map(|c| c / total).collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_partition_covers() {
        let mut rng = Rng::seeded(1);
        let p = random_partition(1000, 10, &mut rng);
        p.validate(1000).unwrap();
        assert!(p.sizes().iter().all(|&s| s > 50));
    }

    #[test]
    fn dirichlet_high_beta_is_balanced() {
        let mut rng = Rng::seeded(2);
        let labels: Vec<u16> = (0..2000).map(|i| (i % 7) as u16).collect();
        let p = dirichlet_partition(&labels, 7, 10, 10_000.0, &mut rng);
        p.validate(2000).unwrap();
        let sizes = p.sizes();
        let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
        assert!(*max < 2 * *min, "IID split should be balanced: {sizes:?}");
        // Per-client label distribution close to global (uniform over 7).
        let skew = label_skew(&p, &labels, 7);
        for dist in skew {
            for pr in dist {
                assert!((pr - 1.0 / 7.0).abs() < 0.08, "non-IID under beta=1e4: {pr}");
            }
        }
    }

    #[test]
    fn dirichlet_low_beta_is_skewed() {
        let mut rng = Rng::seeded(3);
        let labels: Vec<u16> = (0..2000).map(|i| (i % 7) as u16).collect();
        let p = dirichlet_partition(&labels, 7, 10, 0.1, &mut rng);
        p.validate(2000).unwrap();
        let skew = label_skew(&p, &labels, 7);
        // At least one client should be dominated by a single class.
        let max_frac = skew
            .iter()
            .filter(|d| !d.iter().all(|&x| x == 0.0))
            .map(|d| d.iter().cloned().fold(0.0, f64::max))
            .fold(0.0, f64::max);
        assert!(max_frac > 0.5, "expected skew, got max frac {max_frac}");
    }

    #[test]
    fn powerlaw_sizes_decay() {
        let mut rng = Rng::seeded(4);
        let p = powerlaw_partition(100_000, 195, 1.0, &mut rng);
        p.validate(100_000).unwrap();
        let sizes = p.sizes();
        assert!(sizes[0] > sizes[100] && sizes[0] > sizes[194]);
        assert!(sizes.iter().all(|&s| s >= 1));
    }

    #[test]
    fn group_partition_exact() {
        let groups = vec![0u32, 1, 1, 2, 0];
        let p = group_partition(&groups, 3);
        p.validate(5).unwrap();
        assert_eq!(p.members[1], vec![1, 2]);
    }

    #[test]
    fn keyed_dirichlet_matches_v1_law() {
        let labels: Vec<u16> = (0..2000).map(|i| (i % 7) as u16).collect();
        // High β: balanced and near-IID, like the v1 partitioner.
        let props = keyed_dirichlet_props(5, 7, 10, 10_000.0);
        let p = keyed_dirichlet_partition(5, 2000, 10, &props, |u| labels[u]);
        p.validate(2000).unwrap();
        let sizes = p.sizes();
        let (min, max) = (*sizes.iter().min().unwrap(), *sizes.iter().max().unwrap());
        assert!(max < 2 * min.max(1), "IID split should be balanced: {sizes:?}");
        let skew = label_skew(&p, &labels, 7);
        for dist in skew {
            for pr in dist {
                assert!((pr - 1.0 / 7.0).abs() < 0.1, "non-IID under beta=1e4: {pr}");
            }
        }
        // Low β: at least one client dominated by one class.
        let props = keyed_dirichlet_props(6, 7, 10, 0.1);
        let p = keyed_dirichlet_partition(6, 2000, 10, &props, |u| labels[u]);
        p.validate(2000).unwrap();
        let max_frac = label_skew(&p, &labels, 7)
            .iter()
            .filter(|d| !d.iter().all(|&x| x == 0.0))
            .map(|d| d.iter().cloned().fold(0.0, f64::max))
            .fold(0.0, f64::max);
        assert!(max_frac > 0.5, "expected skew, got max frac {max_frac}");
    }

    #[test]
    fn keyed_assignment_is_pointwise_stable() {
        // assign_of(u) computed alone equals the full-partition pass — the
        // O(1) halo-ownership lookup the sliced v2 builds rely on.
        let props = keyed_dirichlet_props(9, 4, 6, 0.5);
        let labels_of = |u: usize| (u % 4) as u16;
        let p = keyed_dirichlet_partition(9, 500, 6, &props, labels_of);
        for u in (0..500).step_by(17) {
            assert_eq!(p.assign[u], keyed_assign_of(9, u, labels_of(u), &props));
        }
    }
}
