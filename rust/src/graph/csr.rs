//! Compressed-sparse-row graph storage.
//!
//! The project's central graph type: undirected (stored symmetric) adjacency
//! in CSR form with u32 node ids. All materialized datasets (cora-sim …
//! arxiv-sim, the TU-style graph-classification sets, the check-in LP sets)
//! use this; papers100m-sim is *lazy* (see `graph::generate::LazyGraph`) and
//! only its per-client subgraphs are ever materialized as `Csr`.

/// CSR adjacency. Invariants (checked by `validate`):
/// - `offsets.len() == n + 1`, monotonically non-decreasing,
///   `offsets[n] == adj.len()`
/// - neighbor lists are sorted and deduplicated
/// - symmetric: `v ∈ adj(u) ⟺ u ∈ adj(v)`
#[derive(Clone, Debug)]
pub struct Csr {
    pub n: usize,
    pub offsets: Vec<u64>,
    pub adj: Vec<u32>,
}

impl Csr {
    /// Build from an undirected edge list. Self-loops and duplicates are
    /// removed; each input edge {u,v} is stored in both directions.
    pub fn from_edges(n: usize, edges: &[(u32, u32)]) -> Csr {
        let mut deg = vec![0u64; n];
        for &(u, v) in edges {
            debug_assert!((u as usize) < n && (v as usize) < n);
            if u == v {
                continue;
            }
            deg[u as usize] += 1;
            deg[v as usize] += 1;
        }
        let mut offsets = vec![0u64; n + 1];
        for i in 0..n {
            offsets[i + 1] = offsets[i] + deg[i];
        }
        let mut adj = vec![0u32; offsets[n] as usize];
        let mut cursor = offsets.clone();
        for &(u, v) in edges {
            if u == v {
                continue;
            }
            adj[cursor[u as usize] as usize] = v;
            cursor[u as usize] += 1;
            adj[cursor[v as usize] as usize] = u;
            cursor[v as usize] += 1;
        }
        // Sort + dedup each row.
        let mut out_adj = Vec::with_capacity(adj.len());
        let mut out_off = vec![0u64; n + 1];
        for u in 0..n {
            let row = &mut adj[offsets[u] as usize..offsets[u + 1] as usize];
            row.sort_unstable();
            let mut prev: Option<u32> = None;
            for &v in row.iter() {
                if prev != Some(v) {
                    out_adj.push(v);
                    prev = Some(v);
                }
            }
            out_off[u + 1] = out_adj.len() as u64;
        }
        Csr { n, offsets: out_off, adj: out_adj }
    }

    #[inline]
    pub fn neighbors(&self, u: u32) -> &[u32] {
        &self.adj[self.offsets[u as usize] as usize..self.offsets[u as usize + 1] as usize]
    }

    #[inline]
    pub fn degree(&self, u: u32) -> usize {
        (self.offsets[u as usize + 1] - self.offsets[u as usize]) as usize
    }

    /// Number of undirected edges (each stored twice).
    pub fn num_edges(&self) -> usize {
        self.adj.len() / 2
    }

    /// Directed (stored) arc count.
    pub fn num_arcs(&self) -> usize {
        self.adj.len()
    }

    pub fn has_edge(&self, u: u32, v: u32) -> bool {
        self.neighbors(u).binary_search(&v).is_ok()
    }

    /// Iterate undirected edges once (u < v).
    pub fn edges(&self) -> impl Iterator<Item = (u32, u32)> + '_ {
        (0..self.n as u32).flat_map(move |u| {
            self.neighbors(u).iter().copied().filter(move |&v| u < v).map(move |v| (u, v))
        })
    }

    /// Check all structural invariants; returns a description of the first
    /// violation. Used by property tests.
    pub fn validate(&self) -> Result<(), String> {
        if self.offsets.len() != self.n + 1 {
            return Err(format!("offsets.len()={} != n+1={}", self.offsets.len(), self.n + 1));
        }
        if self.offsets[0] != 0 || *self.offsets.last().unwrap() != self.adj.len() as u64 {
            return Err("offsets endpoints wrong".into());
        }
        for w in self.offsets.windows(2) {
            if w[1] < w[0] {
                return Err("offsets not monotone".into());
            }
        }
        for u in 0..self.n as u32 {
            let row = self.neighbors(u);
            for w in row.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("row {u} not sorted/deduped"));
                }
            }
            for &v in row {
                if v as usize >= self.n {
                    return Err(format!("edge target {v} out of range"));
                }
                if v == u {
                    return Err(format!("self-loop at {u}"));
                }
                if !self.has_edge(v, u) {
                    return Err(format!("asymmetric edge {u}->{v}"));
                }
            }
        }
        Ok(())
    }

    /// GCN symmetric normalization coefficients with self-loops:
    /// for Â = D̃^{-1/2}(A + I)D̃^{-1/2}, returns the edge list
    /// (src, dst, coeff) *including* the self-loop arcs, where
    /// coeff(u,v) = 1/sqrt(d̃(u)·d̃(v)) and d̃ = deg + 1.
    pub fn gcn_edges(&self) -> Vec<(u32, u32, f32)> {
        let mut out = Vec::with_capacity(self.adj.len() + self.n);
        let dn: Vec<f32> =
            (0..self.n).map(|u| 1.0 / ((self.degree(u as u32) + 1) as f32).sqrt()).collect();
        for u in 0..self.n as u32 {
            out.push((u, u, dn[u as usize] * dn[u as usize]));
            for &v in self.neighbors(u) {
                out.push((u, v, dn[u as usize] * dn[v as usize]));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle_plus_tail() -> Csr {
        // 0-1, 1-2, 2-0, 2-3
        Csr::from_edges(4, &[(0, 1), (1, 2), (2, 0), (2, 3)])
    }

    #[test]
    fn builds_and_validates() {
        let g = triangle_plus_tail();
        g.validate().unwrap();
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.neighbors(2), &[0, 1, 3]);
        assert_eq!(g.degree(3), 1);
    }

    #[test]
    fn dedups_and_drops_self_loops() {
        let g = Csr::from_edges(3, &[(0, 1), (1, 0), (0, 1), (2, 2)]);
        g.validate().unwrap();
        assert_eq!(g.num_edges(), 1);
        assert_eq!(g.degree(2), 0);
    }

    #[test]
    fn edges_iterator_each_once() {
        let g = triangle_plus_tail();
        let es: Vec<_> = g.edges().collect();
        assert_eq!(es.len(), 4);
        assert!(es.iter().all(|&(u, v)| u < v));
    }

    #[test]
    fn gcn_norm_row_structure() {
        let g = triangle_plus_tail();
        let es = g.gcn_edges();
        // arcs + self loops
        assert_eq!(es.len(), g.num_arcs() + g.n);
        // self-loop coefficient for isolated-ish node 3: d̃=2 -> 1/2
        let sl3 = es.iter().find(|&&(u, v, _)| u == 3 && v == 3).unwrap();
        assert!((sl3.2 - 0.5).abs() < 1e-6);
        // symmetry of coefficients
        let c01 = es.iter().find(|&&(u, v, _)| u == 0 && v == 1).unwrap().2;
        let c10 = es.iter().find(|&&(u, v, _)| u == 1 && v == 0).unwrap().2;
        assert_eq!(c01, c10);
    }

    #[test]
    fn empty_graph() {
        let g = Csr::from_edges(0, &[]);
        g.validate().unwrap();
        assert_eq!(g.num_edges(), 0);
    }
}
