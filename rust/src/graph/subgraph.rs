//! Per-client local graph views with cross-client edge tracking.
//!
//! In the paper's federated setting each trainer holds the subgraph induced
//! by its nodes *plus* knowledge of edges that leave the client ("cross-client
//! edges", Table 1 row 4). Different algorithms treat those edges
//! differently:
//! - FedAvg: drops them (trains on the induced subgraph only);
//! - FedGCN: receives pre-aggregated neighbor feature sums for them during
//!   the pre-training communication round;
//! - Distributed-GCN: materializes halo nodes and exchanges their features
//!   every round;
//! - BNS-GCN: samples a fraction of boundary nodes per round.

use std::collections::HashMap;

use super::csr::Csr;
use super::partition::Partition;

/// A client's local view of the global graph.
#[derive(Clone, Debug)]
pub struct LocalGraph {
    pub client: u32,
    /// Global ids of owned nodes (sorted ascending).
    pub owned: Vec<u32>,
    /// Global ids of halo nodes: non-owned endpoints of cross-client edges
    /// (sorted ascending).
    pub halo: Vec<u32>,
    /// Map global id → local index. Owned nodes occupy `[0, owned.len())`,
    /// halo nodes `[owned.len(), owned.len()+halo.len())`.
    pub index: HashMap<u32, u32>,
    /// Local adjacency over owned+halo vertices containing every edge with
    /// at least one owned endpoint (the edges this client knows about).
    pub csr: Csr,
    /// Number of undirected edges fully inside the client.
    pub internal_edges: usize,
    /// Number of undirected edges crossing to another client.
    pub cross_edges: usize,
}

impl LocalGraph {
    pub fn num_owned(&self) -> usize {
        self.owned.len()
    }

    pub fn num_local(&self) -> usize {
        self.owned.len() + self.halo.len()
    }

    pub fn is_owned_local(&self, local: u32) -> bool {
        (local as usize) < self.owned.len()
    }

    /// Global id of a local vertex.
    pub fn global_of(&self, local: u32) -> u32 {
        let l = local as usize;
        if l < self.owned.len() {
            self.owned[l]
        } else {
            self.halo[l - self.owned.len()]
        }
    }
}

/// Build every client's local view in one pass over the global graph.
pub fn build_local_graphs(global: &Csr, part: &Partition) -> Vec<LocalGraph> {
    let mut out = Vec::with_capacity(part.num_clients);
    for c in 0..part.num_clients as u32 {
        out.push(build_local_graph(global, part, c));
    }
    out
}

/// The sorted, deduplicated halo node set of `client` — the non-owned
/// endpoints of its cross-client edges. The **single source of the halo
/// rule**: [`build_local_graph`] materializes views over it and
/// [`halo_count`] sizes it for skipped clients, so the sliced-build RNG
/// contract (one keep/drop draw per halo node) can never drift between the
/// two.
fn halo_nodes(global: &Csr, part: &Partition, client: u32) -> Vec<u32> {
    let mut halo: Vec<u32> = Vec::new();
    for &u in &part.members[client as usize] {
        for &v in global.neighbors(u) {
            if part.assign[v as usize] != client {
                halo.push(v);
            }
        }
    }
    halo.sort_unstable();
    halo.dedup();
    halo
}

/// Build one client's local view.
pub fn build_local_graph(global: &Csr, part: &Partition, client: u32) -> LocalGraph {
    let owned = part.members[client as usize].clone();
    let halo = halo_nodes(global, part, client);
    let mut internal = 0usize;
    let mut cross = 0usize;
    for &u in &owned {
        for &v in global.neighbors(u) {
            if part.assign[v as usize] == client {
                if u < v {
                    internal += 1;
                }
            } else {
                cross += 1;
            }
        }
    }
    let mut index = HashMap::with_capacity(owned.len() + halo.len());
    for (i, &u) in owned.iter().enumerate() {
        index.insert(u, i as u32);
    }
    for (i, &u) in halo.iter().enumerate() {
        index.insert(u, (owned.len() + i) as u32);
    }
    // Local edge list: all global edges with an owned endpoint, remapped.
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity(internal + cross);
    for &u in &owned {
        let lu = index[&u];
        for &v in global.neighbors(u) {
            if let Some(&lv) = index.get(&v) {
                // Each internal edge appears from both endpoints; push once.
                if part.assign[v as usize] == client {
                    if u < v {
                        edges.push((lu, lv));
                    }
                } else {
                    edges.push((lu, lv));
                }
            }
        }
    }
    let csr = Csr::from_edges(owned.len() + halo.len(), &edges);
    LocalGraph { client, owned, halo, index, csr, internal_edges: internal, cross_edges: cross }
}

/// Dataset-format **v2** local view: build one client's [`LocalGraph`]
/// directly from *keyed* per-node adjacency rows, touching only the client's
/// owned nodes — no global CSR exists, and nothing outside
/// `owned ∪ halo(owned)` is ever generated.
///
/// `assign_of` answers global ownership in O(1) (the keyed partition rule);
/// `row_of` yields a node's out-stub targets (duplicates/self-stubs allowed —
/// normalized here and by the CSR build). The local view is the symmetrized
/// union of the owned rows, matching the [`crate::graph::LazyGraph`] stance
/// that a client knows the edges its own nodes initiate. Because every row
/// is a pure function of the node id, the result is bitwise-identical
/// whether this client is built inside a full session or alone in a slice.
///
/// Edge bookkeeping mirrors the stub view: `internal_edges` counts owned→
/// owned stubs (each undirected edge once per initiating stub, pre-dedup),
/// `cross_edges` counts owned→other stubs.
pub fn build_local_graph_keyed(
    client: u32,
    owned: &[u32],
    assign_of: impl Fn(u32) -> u32,
    mut row_of: impl FnMut(u32) -> Vec<u32>,
) -> LocalGraph {
    debug_assert!(owned.windows(2).all(|w| w[0] < w[1]), "owned must be sorted");
    let rows: Vec<(u32, Vec<u32>)> = owned.iter().map(|&u| (u, row_of(u))).collect();
    let mut halo: Vec<u32> = Vec::new();
    let mut internal = 0usize;
    let mut cross = 0usize;
    for (u, row) in &rows {
        for &v in row {
            if v == *u {
                continue;
            }
            if assign_of(v) == client {
                internal += 1;
            } else {
                cross += 1;
                halo.push(v);
            }
        }
    }
    halo.sort_unstable();
    halo.dedup();
    let mut index = HashMap::with_capacity(owned.len() + halo.len());
    for (i, &u) in owned.iter().enumerate() {
        index.insert(u, i as u32);
    }
    for (i, &u) in halo.iter().enumerate() {
        index.insert(u, (owned.len() + i) as u32);
    }
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity(internal + cross);
    for (u, row) in &rows {
        let lu = index[u];
        for &v in row {
            if v == *u {
                continue;
            }
            edges.push((lu, index[&v]));
        }
    }
    let csr = Csr::from_edges(owned.len() + halo.len(), &edges);
    LocalGraph {
        client,
        owned: owned.to_vec(),
        halo,
        index,
        csr,
        internal_edges: internal,
        cross_edges: cross,
    }
}

/// Number of distinct halo nodes `client`'s local view would carry, without
/// building the view (no index map, no local CSR, no feature copies).
///
/// Sliced session builds use this as partition bookkeeping for clients they
/// skip: the halo count drives both the shared artifact-bucket decision and
/// the per-halo-node RNG draws (boundary keep/drop sampling) that must still
/// advance the setup stream for a sliced build to stay bitwise-aligned with
/// a full one.
pub fn halo_count(global: &Csr, part: &Partition, client: u32) -> usize {
    halo_nodes(global, part, client).len()
}

/// Exact 1-hop aggregated neighbor feature sums for a set of nodes, computed
/// over the *global* graph — this is the quantity FedGCN exchanges in its
/// pre-training round (possibly encrypted / low-rank projected). Row `i` of
/// the result is `Σ_{v ∈ N(nodes[i])} x[v]` (global neighborhoods, so the
/// cross-client contribution is included — that is the whole point).
pub fn neighbor_feature_sums(
    global: &Csr,
    features: &[f32],
    dim: usize,
    nodes: &[u32],
) -> Vec<f32> {
    let mut out = vec![0f32; nodes.len() * dim];
    for (i, &u) in nodes.iter().enumerate() {
        let row = &mut out[i * dim..(i + 1) * dim];
        for &v in global.neighbors(u) {
            let f = &features[v as usize * dim..(v as usize + 1) * dim];
            for (o, x) in row.iter_mut().zip(f) {
                *o += x;
            }
        }
    }
    out
}

/// The portion of `neighbor_feature_sums` a single client can compute from
/// its own data: sums restricted to neighbors owned by `client`. Summing this
/// across all clients reproduces the global sums — which is exactly the
/// additive structure that lets the server aggregate *encrypted* per-client
/// contributions (paper §3.2) or *projected* ones (§4.2).
pub fn local_neighbor_contribution(
    global: &Csr,
    part: &Partition,
    features: &[f32],
    dim: usize,
    nodes: &[u32],
    client: u32,
) -> Vec<f32> {
    let mut out = vec![0f32; nodes.len() * dim];
    for (i, &u) in nodes.iter().enumerate() {
        let row = &mut out[i * dim..(i + 1) * dim];
        for &v in global.neighbors(u) {
            if part.assign[v as usize] != client {
                continue;
            }
            let f = &features[v as usize * dim..(v as usize + 1) * dim];
            for (o, x) in row.iter_mut().zip(f) {
                *o += x;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::partition::Partition;

    /// 6-cycle split in halves: clients {0,1,2} and {3,4,5}.
    fn cycle6() -> (Csr, Partition) {
        let g = Csr::from_edges(6, &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (5, 0)]);
        let p = Partition::from_assignment(2, vec![0, 0, 0, 1, 1, 1]);
        (g, p)
    }

    #[test]
    fn local_graph_structure() {
        let (g, p) = cycle6();
        let l0 = build_local_graph(&g, &p, 0);
        assert_eq!(l0.owned, vec![0, 1, 2]);
        assert_eq!(l0.halo, vec![3, 5]); // cross neighbors of 2 and 0
        assert_eq!(l0.internal_edges, 2); // 0-1, 1-2
        assert_eq!(l0.cross_edges, 2); // 2-3, 0-5
        l0.csr.validate().unwrap();
        assert_eq!(l0.csr.num_edges(), 4);
        // local index round trip
        for &u in l0.owned.iter().chain(&l0.halo) {
            assert_eq!(l0.global_of(l0.index[&u]), u);
        }
    }

    #[test]
    fn halo_count_matches_built_view() {
        let (g, p) = cycle6();
        for c in 0..2u32 {
            let l = build_local_graph(&g, &p, c);
            assert_eq!(halo_count(&g, &p, c), l.halo.len());
        }
    }

    #[test]
    fn cross_edge_totals_are_consistent() {
        let (g, p) = cycle6();
        let locals = build_local_graphs(&g, &p);
        let total_cross: usize = locals.iter().map(|l| l.cross_edges).sum();
        // Each cross edge counted once per side.
        assert_eq!(total_cross, 4);
        let total_internal: usize = locals.iter().map(|l| l.internal_edges).sum();
        assert_eq!(total_internal + total_cross / 2, g.num_edges());
    }

    #[test]
    fn neighbor_sums_decompose_across_clients() {
        let (g, p) = cycle6();
        let dim = 3;
        let feats: Vec<f32> = (0..6 * dim).map(|i| i as f32 * 0.5).collect();
        let nodes = [0u32, 2, 4];
        let global_sums = neighbor_feature_sums(&g, &feats, dim, &nodes);
        let mut acc = vec![0f32; nodes.len() * dim];
        for c in 0..2 {
            let part_sum = local_neighbor_contribution(&g, &p, &feats, dim, &nodes, c);
            for (a, b) in acc.iter_mut().zip(&part_sum) {
                *a += b;
            }
        }
        for (a, b) in acc.iter().zip(&global_sums) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn keyed_local_graph_is_slice_independent() {
        // Rows are a pure function of the node id; building client 0's view
        // alone must equal building it alongside every other client.
        let rows = |u: u32| -> Vec<u32> {
            // tiny deterministic stub rule over 6 nodes
            vec![(u + 1) % 6, (u + 3) % 6]
        };
        let assign = |v: u32| v / 3; // {0,1,2} vs {3,4,5}
        let alone = build_local_graph_keyed(0, &[0, 1, 2], assign, rows);
        let _other = build_local_graph_keyed(1, &[3, 4, 5], assign, rows);
        let again = build_local_graph_keyed(0, &[0, 1, 2], assign, rows);
        assert_eq!(alone.owned, again.owned);
        assert_eq!(alone.halo, again.halo);
        assert_eq!(alone.csr.adj, again.csr.adj);
        assert_eq!(alone.csr.offsets, again.csr.offsets);
        assert_eq!(alone.internal_edges, again.internal_edges);
        assert_eq!(alone.cross_edges, again.cross_edges);
        alone.csr.validate().unwrap();
        // halo = cross targets of owned rows: 0->3, 1->4, 2->3,5
        assert_eq!(alone.halo, vec![3, 4, 5]);
        for &u in alone.owned.iter().chain(&alone.halo) {
            assert_eq!(alone.global_of(alone.index[&u]), u);
        }
    }

    #[test]
    fn neighbor_sum_values() {
        let g = Csr::from_edges(3, &[(0, 1), (0, 2)]);
        let feats = vec![1.0, 10.0, 100.0]; // dim=1
        let sums = neighbor_feature_sums(&g, &feats, 1, &[0, 1]);
        assert_eq!(sums, vec![110.0, 1.0]);
    }
}
