//! Privacy-preserving aggregation substrates: the CKKS-style homomorphic
//! encryption simulator (paper §3.2, Appendix F) and the Gaussian-mechanism
//! differential privacy option (Appendix A.5).

pub mod ckks;
pub mod dp;

pub use ckks::{Ciphertext, CkksContext, CkksParams};
pub use dp::{clip_l2, gaussian_mechanism, DpParams};
