//! Privacy-preserving aggregation substrates: the CKKS-style homomorphic
//! encryption simulator (paper §3.2, Appendix F) and the Gaussian-mechanism
//! differential privacy option (Appendix A.5).
//!
//! Both are applied **client-side** inside the trainer actor
//! ([`crate::federation::actor`]): DP noises the uploaded copy with the
//! client's own RNG stream (the client keeps its exact local model), HE
//! pre-scales by the coordinator-assigned aggregation share and encrypts
//! under the session context (coordinator and workers derive the same keys
//! from the config seed). Composition rules (enforced by
//! [`crate::config::FedGraphConfig::validate`]): DP costs plaintext
//! bandwidth and composes with everything; HE excludes `federation.mode:
//! async` (staleness re-weighting cannot rescale ciphertexts) and
//! `federation.compression: quantized` (ciphertexts cannot be
//! delta-quantized), while the lossless `pack` codec simply never sees a
//! ciphertext upload. See `docs/CONFIG.md` for the full matrix.

pub mod ckks;
pub mod dp;

pub use ckks::{Ciphertext, CkksContext, CkksParams};
pub use dp::{clip_l2, gaussian_mechanism, DpParams};
