//! Differential privacy for aggregation (paper Appendix A.5).
//!
//! FedGraph offers DP as a lighter-weight alternative to HE: the Gaussian
//! mechanism applied to client contributions before aggregation. Unlike HE,
//! DP adds no communication overhead (Table 3 shows ~identical comm to
//! plaintext) at the cost of calibrated noise in the aggregate.

use crate::util::rng::Rng;

/// Gaussian-mechanism parameters.
#[derive(Clone, Debug)]
pub struct DpParams {
    pub epsilon: f64,
    pub delta: f64,
    /// L2 clipping bound applied to each client's contribution.
    pub clip_norm: f64,
}

impl DpParams {
    pub fn default_params() -> DpParams {
        DpParams { epsilon: 8.0, delta: 1e-5, clip_norm: 10.0 }
    }

    /// Noise std for the Gaussian mechanism:
    /// σ = clip · sqrt(2 ln(1.25/δ)) / ε  (classic analytic bound).
    pub fn sigma(&self) -> f64 {
        self.clip_norm * (2.0 * (1.25 / self.delta).ln()).sqrt() / self.epsilon
    }
}

/// Clip a vector to the L2 bound in place; returns the pre-clip norm.
pub fn clip_l2(v: &mut [f32], bound: f64) -> f64 {
    let norm = (v.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>()).sqrt();
    if norm > bound && norm > 0.0 {
        let s = (bound / norm) as f32;
        for x in v.iter_mut() {
            *x *= s;
        }
    }
    norm
}

/// Apply the Gaussian mechanism: clip then add N(0, σ²) per coordinate.
pub fn gaussian_mechanism(v: &mut [f32], params: &DpParams, rng: &mut Rng) {
    clip_l2(v, params.clip_norm);
    let sigma = params.sigma();
    for x in v.iter_mut() {
        *x += (rng.normal() * sigma) as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sigma_decreases_with_epsilon() {
        let lo = DpParams { epsilon: 1.0, ..DpParams::default_params() };
        let hi = DpParams { epsilon: 10.0, ..DpParams::default_params() };
        assert!(lo.sigma() > hi.sigma());
    }

    #[test]
    fn clip_preserves_small_vectors() {
        let mut v = vec![0.1f32, 0.2];
        let norm = clip_l2(&mut v, 10.0);
        assert!(norm < 1.0);
        assert_eq!(v, vec![0.1, 0.2]);
    }

    #[test]
    fn clip_shrinks_large_vectors() {
        let mut v = vec![30.0f32, 40.0]; // norm 50
        clip_l2(&mut v, 5.0);
        let n = (v[0] * v[0] + v[1] * v[1]).sqrt();
        assert!((n - 5.0).abs() < 1e-4);
        // direction preserved
        assert!((v[1] / v[0] - 4.0 / 3.0).abs() < 1e-4);
    }

    #[test]
    fn mechanism_perturbs_but_preserves_signal() {
        let mut rng = Rng::seeded(1);
        let p = DpParams { epsilon: 8.0, delta: 1e-5, clip_norm: 1000.0 };
        let clean: Vec<f32> = (0..10_000).map(|i| (i % 10) as f32).collect();
        let mut noisy = clean.clone();
        gaussian_mechanism(&mut noisy, &p, &mut rng);
        assert!(noisy != clean);
        let mean_err: f64 = clean
            .iter()
            .zip(&noisy)
            .map(|(a, b)| (a - b).abs() as f64)
            .sum::<f64>()
            / clean.len() as f64;
        // ~sigma on average, and the aggregate mean is nearly unbiased
        assert!(mean_err > 0.0 && mean_err < 10.0 * p.sigma() + 1.0);
        let m_clean: f64 = clean.iter().map(|&x| x as f64).sum::<f64>() / clean.len() as f64;
        let m_noisy: f64 = noisy.iter().map(|&x| x as f64).sum::<f64>() / noisy.len() as f64;
        // The noise is zero-mean: the empirical mean shifts by
        // ~sigma/sqrt(n); allow 4 standard errors.
        let se = p.sigma() / (clean.len() as f64).sqrt();
        assert!((m_clean - m_noisy).abs() < 4.0 * se + 1e-9);
    }
}
