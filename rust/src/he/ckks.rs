//! CKKS-style homomorphic-encryption simulator.
//!
//! The paper uses TenSEAL CKKS for (i) pre-training feature aggregation and
//! (ii) model-update aggregation (§3.2, Appendix F). TenSEAL is unavailable
//! offline, so this module is a *behaviorally calibrated* substitute that
//! reproduces the three observable effects the paper measures:
//!
//! 1. **Ciphertext expansion → communication cost.** Sizes follow the real
//!    CKKS formulas: a ciphertext holds `N/2` complex slots (we use the real
//!    packing convention of N/2 values) and serializes to
//!    `2 · N · ceil(Σ coeff_bits / 8)` bytes; keys likewise. These are exact,
//!    which is what Fig 5 / Table 3 / Table 7 measure.
//! 2. **Encrypt/decrypt/add compute overhead.** Encode/decode run a real
//!    O(N log N) butterfly pass per polynomial (an NTT-shaped workload) so
//!    measured times scale with `poly_mod_degree` the way TenSEAL's do.
//! 3. **Precision behaviour.** Values are fixed-point encoded at
//!    `2^scale_bits`; additions accumulate noise; undersized parameter sets
//!    (poly degree below the dataset requirement `N ≥ 2·max(nodes, feats)`,
//!    or scale too large for the modulus chain) degrade or destroy accuracy
//!    — reproducing Appendix A.6 / Table 7.
//!
//! The homomorphic property is *real* for addition (the only operation the
//! FedGraph aggregation path needs): `dec(enc(a) + enc(b)) ≈ a + b` without
//! the server seeing plaintext in this simulation's threat model.

use crate::util::rng::Rng;

/// CKKS parameter set (paper Table 6).
#[derive(Clone, Debug, PartialEq)]
pub struct CkksParams {
    /// Polynomial modulus degree N ∈ {4096, 8192, 16384, 32768}.
    pub poly_mod_degree: usize,
    /// Coefficient modulus chain bit sizes, e.g. [60, 40, 40, 40, 60].
    pub coeff_mod_bits: Vec<u32>,
    /// Global scale exponent: values are encoded at 2^scale_bits.
    pub scale_bits: u32,
    /// Claimed security level in bits (128 / 192 / 256).
    pub security_level: u32,
}

impl CkksParams {
    /// The paper's default configuration (Table 6).
    pub fn default_params() -> CkksParams {
        CkksParams {
            poly_mod_degree: 16384,
            coeff_mod_bits: vec![60, 40, 40, 40, 60],
            scale_bits: 40,
            security_level: 128,
        }
    }

    pub fn with_degree(degree: usize) -> CkksParams {
        let coeff = match degree {
            4096 => vec![40, 20, 40],
            8192 => vec![60, 40, 40, 60],
            16384 => vec![60, 40, 40, 40, 60],
            _ => vec![60, 40, 40, 40, 60],
        };
        CkksParams {
            poly_mod_degree: degree,
            coeff_mod_bits: coeff,
            scale_bits: 40,
            security_level: 128,
        }
    }

    /// Number of packed real values per ciphertext.
    pub fn slots(&self) -> usize {
        self.poly_mod_degree / 2
    }

    pub fn total_coeff_bits(&self) -> u32 {
        self.coeff_mod_bits.iter().sum()
    }

    /// Serialized size of ONE ciphertext: two ring polynomials of N
    /// coefficients, each coefficient stored across the modulus chain.
    pub fn ciphertext_bytes(&self) -> u64 {
        2 * self.poly_mod_degree as u64 * ((self.total_coeff_bits() as u64 + 7) / 8)
    }

    /// Serialized size of the public key (same shape as a ciphertext).
    pub fn public_key_bytes(&self) -> u64 {
        self.ciphertext_bytes()
    }

    /// Bytes to ship a vector of `len` f32 values encrypted.
    pub fn encrypted_vector_bytes(&self, len: usize) -> u64 {
        let chunks = (len + self.slots() - 1) / self.slots();
        chunks as u64 * self.ciphertext_bytes()
    }

    /// The paper's sizing rule (Table 6): N must be at least
    /// 2 × max(nodes, features) for valid packing of the graph matrices.
    pub fn satisfies_requirement(&self, max_dim: usize) -> bool {
        self.poly_mod_degree >= 2 * max_dim
    }

    /// Headroom (in bits) between the scale and the modulus chain; when this
    /// goes non-positive the encryption is effectively invalid and decryption
    /// returns garbage (Appendix A.6's "accuracy drops sharply").
    pub fn precision_headroom(&self) -> i64 {
        // The first and last primes anchor the scale; the middle chain is the
        // compute budget.
        self.total_coeff_bits() as i64 - self.scale_bits as i64 - 60
    }
}

/// Encrypted vector: `chunks` ciphertexts of `slots` fixed-point values.
#[derive(Clone, Debug)]
pub struct Ciphertext {
    pub params: CkksParams,
    /// Encoded fixed-point slots; kept as i64 in "poly" (butterfly'd) domain.
    data: Vec<i64>,
    /// Logical length of the encoded f32 vector.
    pub len: usize,
    /// Number of homomorphic additions accumulated (noise bookkeeping).
    pub adds: u32,
    /// Whether the parameter set was valid for the encoded data.
    valid: bool,
}

impl Ciphertext {
    /// Serialized wire size of this ciphertext vector.
    pub fn wire_bytes(&self) -> u64 {
        self.params.encrypted_vector_bytes(self.len)
    }

    pub fn num_chunks(&self) -> usize {
        (self.len + self.params.slots() - 1) / self.params.slots()
    }

    /// Serialize into a federation-protocol frame. This is the *simulator's*
    /// representation (fixed-point slots); communication accounting must keep
    /// using [`Ciphertext::wire_bytes`], which follows the real CKKS size
    /// formulas.
    pub fn encode_into(&self, w: &mut crate::transport::serialize::Writer) {
        w.u32(self.params.poly_mod_degree as u32);
        w.u32(self.params.coeff_mod_bits.len() as u32);
        for &b in &self.params.coeff_mod_bits {
            w.u32(b);
        }
        w.u32(self.params.scale_bits);
        w.u32(self.params.security_level);
        w.u64(self.len as u64);
        w.u32(self.adds);
        w.u8(self.valid as u8);
        w.i64s(&self.data);
    }

    /// Inverse of [`Ciphertext::encode_into`].
    pub fn decode_from(
        r: &mut crate::transport::serialize::Reader<'_>,
    ) -> Result<Ciphertext, crate::transport::serialize::WireError> {
        let poly_mod_degree = r.u32()? as usize;
        let n_coeff = r.u32()? as usize;
        let mut coeff_mod_bits = Vec::with_capacity(n_coeff);
        for _ in 0..n_coeff {
            coeff_mod_bits.push(r.u32()?);
        }
        let scale_bits = r.u32()?;
        let security_level = r.u32()?;
        let len = r.u64()? as usize;
        let adds = r.u32()?;
        let valid = r.u8()? != 0;
        let data = r.i64s()?;
        Ok(Ciphertext {
            params: CkksParams { poly_mod_degree, coeff_mod_bits, scale_bits, security_level },
            data,
            len,
            adds,
            valid,
        })
    }
}

/// A CKKS-sim context: holds the parameter set and the (simulated) keys.
#[derive(Clone, Debug)]
pub struct CkksContext {
    pub params: CkksParams,
    noise_seed: u64,
}

/// The butterfly pass standing in for the NTT: `log2(n)` rounds of paired
/// add/sub with a data-dependent rotation. Self-inverse is NOT required —
/// we apply `forward` at encryption and `inverse` at decryption so the
/// round-trip is exact; the point is to do O(N log N) integer work shaped
/// like the real transform.
fn butterfly_forward(data: &mut [i64]) {
    let n = data.len();
    let mut half = 1;
    while half < n {
        let mut i = 0;
        while i < n {
            let j = i + half;
            if j < n {
                let a = data[i];
                let b = data[j];
                data[i] = a.wrapping_add(b);
                data[j] = a.wrapping_sub(b);
            }
            i += 2 * half;
        }
        half *= 2;
    }
}

fn butterfly_inverse(data: &mut [i64]) {
    let n = data.len();
    let mut half = n / 2;
    while half >= 1 {
        let mut i = 0;
        while i < n {
            let j = i + half;
            if j < n {
                let a = data[i];
                let b = data[j];
                // inverse of (a+b, a-b) is ((a'+b')/2, (a'-b')/2)
                data[i] = (a.wrapping_add(b)) >> 1;
                data[j] = (a.wrapping_sub(b)) >> 1;
            }
            i += 2 * half;
        }
        half /= 2;
    }
}

impl CkksContext {
    pub fn new(params: CkksParams, seed: u64) -> CkksContext {
        CkksContext { params, noise_seed: seed }
    }

    /// Encrypt an f32 vector. `max_dim` is the dataset's max(nodes, features)
    /// used for the paper's validity rule.
    pub fn encrypt(&self, values: &[f32], max_dim: usize) -> Ciphertext {
        let scale = (1u64 << self.params.scale_bits.min(62)) as f64;
        let slots = self.params.slots();
        let chunks = (values.len() + slots - 1) / slots;
        let mut data = vec![0i64; chunks * slots];
        let valid = self.params.satisfies_requirement(max_dim)
            && self.params.precision_headroom() > 0;
        let mut rng = Rng::seeded(self.noise_seed ^ values.len() as u64);
        for (i, &v) in values.iter().enumerate() {
            // Fresh encryption noise: tiny (sub-LSB) when valid; destructive
            // when the parameter set is undersized.
            let noise = if valid {
                rng.normal() * 0.5 // half an LSB of the fixed-point code
            } else {
                rng.normal() * scale * 0.05 * (1.0 + v.abs() as f64)
            };
            data[i] = (v as f64 * scale + noise).round() as i64;
        }
        // NTT-shaped work per chunk (cost model). The transform runs on a
        // scratch copy: ciphertext data stays in coefficient domain so that
        // homomorphic addition is exact for arbitrarily large aggregates
        // (the butterfly's magnitude growth would otherwise overflow i64 on
        // deep chains of adds — a simulator artifact, not CKKS behaviour).
        let mut scratch = data.clone();
        for c in 0..chunks {
            butterfly_forward(&mut scratch[c * slots..(c + 1) * slots]);
        }
        std::hint::black_box(&scratch);
        Ciphertext { params: self.params.clone(), data, len: values.len(), adds: 0, valid }
    }

    /// Homomorphic addition (the only op the aggregation path needs).
    pub fn add_assign(&self, acc: &mut Ciphertext, other: &Ciphertext) {
        assert_eq!(acc.params, other.params, "ciphertext parameter mismatch");
        assert_eq!(acc.len, other.len, "ciphertext length mismatch");
        for (a, b) in acc.data.iter_mut().zip(&other.data) {
            *a = a.wrapping_add(*b);
        }
        acc.adds += other.adds + 1;
        acc.valid &= other.valid;
    }

    pub fn add(&self, a: &Ciphertext, b: &Ciphertext) -> Ciphertext {
        let mut out = a.clone();
        self.add_assign(&mut out, b);
        out
    }

    /// Homomorphic sum of many ciphertexts with the slot space chunked
    /// across a scoped worker pool (the coordinator's sharded reduce for
    /// 1000-client aggregation). **Bitwise-identical to the serial
    /// [`CkksContext::add_assign`] fold for every shard count**: slot
    /// addition is exact wrapping integer arithmetic, so per-slot order is
    /// irrelevant, and the `adds`/`valid` noise bookkeeping replays the
    /// serial fold after the workers join.
    pub fn sum_sharded(&self, cts: &[&Ciphertext], shards: usize) -> Ciphertext {
        assert!(!cts.is_empty(), "nothing to sum");
        let mut acc = cts[0].clone();
        if cts.len() == 1 {
            return acc;
        }
        for ct in &cts[1..] {
            assert_eq!(acc.params, ct.params, "ciphertext parameter mismatch");
            assert_eq!(acc.len, ct.len, "ciphertext length mismatch");
        }
        let n = acc.data.len();
        let shards = shards.max(1).min(n.max(1));
        if shards == 1 {
            for ct in &cts[1..] {
                self.add_assign(&mut acc, ct);
            }
            return acc;
        }
        let chunk = (n + shards - 1) / shards;
        std::thread::scope(|scope| {
            for (k, slice) in acc.data.chunks_mut(chunk).enumerate() {
                let off = k * chunk;
                scope.spawn(move || {
                    for ct in &cts[1..] {
                        let src = &ct.data[off..off + slice.len()];
                        for (a, b) in slice.iter_mut().zip(src) {
                            *a = a.wrapping_add(*b);
                        }
                    }
                });
            }
        });
        for ct in &cts[1..] {
            acc.adds += ct.adds + 1;
            acc.valid &= ct.valid;
        }
        acc
    }

    /// Decrypt back to f32. Noise grows with the number of additions; with
    /// invalid parameters the output is visibly corrupted.
    pub fn decrypt(&self, ct: &Ciphertext) -> Vec<f32> {
        let scale = (1u64 << self.params.scale_bits.min(62)) as f64;
        let slots = self.params.slots();
        let data = &ct.data;
        // NTT-shaped work per chunk (cost model; see `encrypt`).
        let mut scratch = ct.data.clone();
        let chunks = scratch.len() / slots;
        for c in 0..chunks {
            butterfly_inverse(&mut scratch[c * slots..(c + 1) * slots]);
        }
        std::hint::black_box(&scratch);
        let mut rng = Rng::seeded(self.noise_seed ^ 0xDEC ^ ct.len as u64);
        // Decryption noise: sub-LSB per accumulated addition when valid.
        let noise_std = 0.5 * ((1 + ct.adds) as f64).sqrt();
        data.iter()
            .take(ct.len)
            .map(|&q| {
                let n = if ct.valid { rng.normal() * noise_std } else { 0.0 };
                ((q as f64 + n) / scale) as f32
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> CkksContext {
        CkksContext::new(CkksParams::default_params(), 42)
    }

    #[test]
    fn sizes_match_ckks_formulas() {
        let p = CkksParams::default_params();
        assert_eq!(p.slots(), 8192);
        assert_eq!(p.total_coeff_bits(), 240);
        assert_eq!(p.ciphertext_bytes(), 2 * 16384 * 30); // 983 040
        // 10_000 floats -> 2 chunks
        assert_eq!(p.encrypted_vector_bytes(10_000), 2 * 983_040);
        // Expansion vs plaintext is large (the paper's whole point)
        let plain = 10_000u64 * 4;
        assert!(p.encrypted_vector_bytes(10_000) > 20 * plain);
    }

    #[test]
    fn roundtrip_is_accurate_when_valid() {
        let ctx = ctx();
        let v: Vec<f32> = (0..1000).map(|i| (i as f32 - 500.0) * 0.01).collect();
        let ct = ctx.encrypt(&v, 2708);
        let out = ctx.decrypt(&ct);
        for (a, b) in v.iter().zip(&out) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn ciphertext_wire_roundtrip() {
        use crate::transport::serialize::{Reader, Writer};
        let ctx = ctx();
        let v: Vec<f32> = (0..300).map(|i| i as f32 * 0.25).collect();
        let ct = ctx.encrypt(&v, 300);
        let mut w = Writer::new();
        ct.encode_into(&mut w);
        let bytes = w.finish();
        let mut r = Reader::open(&bytes).unwrap();
        let back = Ciphertext::decode_from(&mut r).unwrap();
        assert_eq!(back.params, ct.params);
        assert_eq!(back.len, ct.len);
        assert_eq!(back.adds, ct.adds);
        assert_eq!(back.wire_bytes(), ct.wire_bytes());
        // Decrypting the decoded ciphertext gives the same values.
        assert_eq!(ctx.decrypt(&back), ctx.decrypt(&ct));
    }

    #[test]
    fn addition_is_homomorphic() {
        let ctx = ctx();
        let a: Vec<f32> = (0..500).map(|i| i as f32 * 0.1).collect();
        let b: Vec<f32> = (0..500).map(|i| 50.0 - i as f32 * 0.1).collect();
        let ca = ctx.encrypt(&a, 500);
        let cb = ctx.encrypt(&b, 500);
        let sum = ctx.add(&ca, &cb);
        let out = ctx.decrypt(&sum);
        for x in out {
            assert!((x - 50.0).abs() < 1e-3, "{x}");
        }
    }

    #[test]
    fn many_party_aggregation() {
        let ctx = ctx();
        let parties = 10;
        let v: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let mut acc = ctx.encrypt(&v, 100);
        for _ in 1..parties {
            let ct = ctx.encrypt(&v, 100);
            ctx.add_assign(&mut acc, &ct);
        }
        let out = ctx.decrypt(&acc);
        for (i, x) in out.iter().enumerate() {
            let expect = i as f32 * parties as f32;
            assert!((x - expect).abs() < 0.05, "slot {i}: {x} vs {expect}");
        }
    }

    #[test]
    fn sharded_sum_bitwise_equals_serial_fold() {
        let ctx = ctx();
        let parties: Vec<Ciphertext> = (0..5)
            .map(|k| {
                let v: Vec<f32> = (0..10_000).map(|i| (i + k * 7) as f32 * 0.01).collect();
                ctx.encrypt(&v, 8192)
            })
            .collect();
        let refs: Vec<&Ciphertext> = parties.iter().collect();
        let mut serial = parties[0].clone();
        for ct in &parties[1..] {
            ctx.add_assign(&mut serial, ct);
        }
        for shards in [1usize, 2, 7] {
            let sharded = ctx.sum_sharded(&refs, shards);
            assert_eq!(sharded.data, serial.data, "slot data drifted at {shards} shards");
            assert_eq!(sharded.adds, serial.adds, "noise bookkeeping drifted");
            assert_eq!(sharded.valid, serial.valid);
            assert_eq!(ctx.decrypt(&sharded), ctx.decrypt(&serial));
        }
        // Degenerate single-party "sum".
        let one = ctx.sum_sharded(&refs[..1], 7);
        assert_eq!(one.data, parties[0].data);
    }

    #[test]
    fn undersized_params_corrupt_decryption() {
        // Cora needs N >= 2*2708; 4096 violates it -> Appendix A.6 behaviour.
        let small = CkksContext::new(CkksParams::with_degree(4096), 1);
        let v = vec![1.0f32; 256];
        let ct = small.encrypt(&v, 2708);
        let out = small.decrypt(&ct);
        let err: f32 = out.iter().map(|x| (x - 1.0).abs()).sum::<f32>() / 256.0;
        assert!(err > 0.01, "expected visible corruption, err={err}");
    }

    #[test]
    fn butterfly_roundtrip_exact() {
        let mut data: Vec<i64> = (0..64).map(|i| (i * 31 - 1000) as i64).collect();
        let orig = data.clone();
        butterfly_forward(&mut data);
        assert_ne!(data, orig);
        butterfly_inverse(&mut data);
        assert_eq!(data, orig);
    }

    #[test]
    fn wire_bytes_counts_chunks() {
        let ctx = ctx();
        let ct = ctx.encrypt(&vec![0.5f32; 8193], 8192);
        assert_eq!(ct.num_chunks(), 2);
        assert_eq!(ct.wire_bytes(), 2 * ctx.params.ciphertext_bytes());
    }
}
