//! `fedgraph` CLI — the launcher (hand-rolled argument parsing; clap is not
//! available offline).
//!
//! Usage:
//!   fedgraph run --config configs/cora_fedgcn.yaml [--json out.json]
//!   fedgraph run --task NC --dataset cora-sim --method FedGCN [--rounds N]
//!               [--trainers M] [--scale S] [--he] [--dp] [--lowrank K]
//!               [--transport channel|tcp --listen-addr H:P --workers W]
//!   fedgraph worker --connect <host:port>   # host trainer actors for a
//!                                           # tcp-transport coordinator
//!   fedgraph list                 # supported task/method/dataset matrix
//!   fedgraph artifacts            # show the loaded artifact manifest

use std::process::ExitCode;

use fedgraph::config::{
    CompressionMode, DatasetFormat, EntropyMode, FedGraphConfig, FederationMode, Method,
    PrivacyMode, Task, TransportKind,
};
use fedgraph::data;
use fedgraph::he::{CkksParams, DpParams};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        Some("run") => cmd_run(&args[1..]),
        Some("worker") => cmd_worker(&args[1..]),
        Some("launch") => cmd_launch(&args[1..]),
        Some("list") => cmd_list(),
        Some("artifacts") => cmd_artifacts(&args[1..]),
        Some("--help") | Some("-h") | None => {
            print_help();
            ExitCode::SUCCESS
        }
        Some(other) => {
            eprintln!("unknown command '{other}'\n");
            print_help();
            ExitCode::FAILURE
        }
    }
}

fn print_help() {
    println!(
        "fedgraph — federated graph learning benchmark (FedGraph reproduction)\n\n\
         commands:\n\
         \x20 run --config <file.yaml> [--json <out.json>] [--trace <out.trace.json>]\n\
         \x20 run --task NC|GC|LP --dataset <name> --method <name>\n\
         \x20     [--rounds N] [--trainers M] [--local-steps K] [--lr F]\n\
         \x20     [--scale S] [--beta B] [--batch-size B] [--he] [--dp]\n\
         \x20     [--lowrank K] [--hops H] [--sample-ratio R] [--seed S]\n\
         \x20     [--dataset-format v1|v2]\n\
         \x20     [--concurrency K] [--dropout F] [--straggler-ms MS]\n\
         \x20     [--mode sync|async] [--max-staleness N] [--buffer-size N]\n\
         \x20     [--agg-shards N]\n\
         \x20     [--heartbeat-ms MS] [--worker-timeout-ms MS]\n\
         \x20     [--checkpoint-every N] [--checkpoint-dir DIR]\n\
         \x20     [--reconnect-grace-ms MS] [--resume DIR]\n\
         \x20     [--transport channel|tcp] [--listen-addr HOST:PORT]\n\
         \x20     [--workers W]\n\
         \x20     [--compression none|pack|quantized] [--quantized-bits 4|8]\n\
         \x20     [--entropy none|rans] [--no-error-feedback]\n\
         \x20     [--trace <out.trace.json>]\n\
         \x20     --trace records a cross-process span timeline (coordinator,\n\
         \x20     trainer actors, codec, sockets, workers) and writes Chrome\n\
         \x20     trace-event JSON loadable in Perfetto; the run itself is\n\
         \x20     bitwise-identical to an untraced one\n\
         \x20     --dataset-format v1 (default) keeps the sequential-stream\n\
         \x20     generators; v2 switches to counter-based keyed generation\n\
         \x20     so each worker generates only its assigned slice\n\
         \x20     (O(assigned nodes) startup work and memory). The two\n\
         \x20     formats are statistically matched but bitwise different.\n\
         \x20     --compression pack is lossless and bitwise-identical to\n\
         \x20     none in both directions (only measured wire bytes shrink);\n\
         \x20     quantized is a lossy int8/int4 upload-delta codec\n\
         \x20     (plaintext/DP only); --entropy rans adds a lossless rANS\n\
         \x20     entropy stage behind the pack codec\n\
         \x20     With --transport tcp the run waits for W `fedgraph worker`\n\
         \x20     processes to connect; results are bitwise-identical to the\n\
         \x20     in-process channel transport for the same config/seed.\n\
         \x20     --heartbeat-ms / --worker-timeout-ms tune tcp liveness\n\
         \x20     detection (timeout 0 disables it); a crashed worker's\n\
         \x20     clients are re-assigned to survivors and the round resumes\n\
         \x20     (sync runs stay bitwise-identical). --checkpoint-every N\n\
         \x20     snapshots coordinator state every N rounds (0 = off);\n\
         \x20     --checkpoint-dir DIR persists each snapshot durably and\n\
         \x20     --resume DIR boots a fresh coordinator from the newest\n\
         \x20     valid snapshot in DIR; --reconnect-grace-ms MS holds\n\
         \x20     recovery while a disconnected worker redials with its\n\
         \x20     session token; see docs/FAULT_TOLERANCE.md.\n\
         \x20 worker --connect <host:port> [--artifacts DIR] [--timeout-secs S]\n\
         \x20     host trainer actors for a tcp-transport coordinator: the\n\
         \x20     worker receives its client assignment + config over the\n\
         \x20     socket, rebuilds the session deterministically, and exits 0\n\
         \x20     when the coordinator finishes the run; a lost coordinator\n\
         \x20     socket triggers reconnect with backoff, not an exit\n\
         \x20 launch --workers W [--listen-addr HOST:PORT] [--max-restarts K]\n\
         \x20        [--compose <out.yaml>] <run flags...>\n\
         \x20     supervise a whole local deployment: spawn one tcp\n\
         \x20     coordinator (`run <run flags>`) plus W worker processes,\n\
         \x20     monitor them, and respawn dead workers as standbys (at most\n\
         \x20     K restarts, default 5). --compose writes a compose-style\n\
         \x20     manifest for the equivalent multi-machine deployment\n\
         \x20     instead of launching anything; see docs/DEPLOYMENT.md\n\
         \x20 list       supported task/method/dataset matrix\n\
         \x20 artifacts  show the artifact manifest"
    );
}

fn cmd_worker(args: &[String]) -> ExitCode {
    let Some(addr) = flag_value(args, "--connect") else {
        eprintln!("worker needs --connect <host:port> (the coordinator's listen_addr)");
        return ExitCode::FAILURE;
    };
    let artifacts = flag_value(args, "--artifacts");
    let timeout_secs: u64 = flag_value(args, "--timeout-secs")
        .and_then(|v| v.parse().ok())
        .unwrap_or(30);
    match fedgraph::federation::worker::run_worker(
        addr,
        artifacts,
        std::time::Duration::from_secs(timeout_secs),
    ) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("worker failed: {e:#}");
            ExitCode::FAILURE
        }
    }
}

/// `fedgraph launch`: the supervising launcher. Spawns one TCP coordinator
/// (`fedgraph run <passthrough flags>`) plus `--workers` local worker
/// processes, then babysits the fleet: a worker that dies mid-run is
/// respawned as a standby (the coordinator re-slices it in through the
/// elastic `Reassign` machinery), bounded by `--max-restarts`. The
/// supervisor's exit code is the coordinator's. With `--compose <path>` it
/// writes a compose-style manifest for the equivalent multi-machine
/// deployment instead of launching anything.
fn cmd_launch(args: &[String]) -> ExitCode {
    let workers: usize = match flag_value(args, "--workers").map(|v| v.parse::<usize>()) {
        Some(Ok(w)) if w > 0 => w,
        Some(_) => {
            eprintln!("launch needs --workers W with W >= 1");
            return ExitCode::FAILURE;
        }
        None => 2,
    };
    let addr = flag_value(args, "--listen-addr").unwrap_or("127.0.0.1:8471").to_string();
    let max_restarts: usize =
        flag_value(args, "--max-restarts").and_then(|v| v.parse().ok()).unwrap_or(5);
    let run_args = passthrough_run_args(args);
    if let Some(path) = flag_value(args, "--compose") {
        return write_compose_manifest(path, workers, &addr, &run_args);
    }
    let exe = match std::env::current_exe() {
        Ok(e) => e,
        Err(e) => {
            eprintln!("cannot resolve the fedgraph binary path: {e}");
            return ExitCode::FAILURE;
        }
    };
    let spawn_worker = |k: usize| -> std::io::Result<std::process::Child> {
        let ch = std::process::Command::new(&exe)
            .args(["worker", "--connect", &addr])
            .spawn()?;
        eprintln!("fedgraph launch: worker {k} is pid {}", ch.id());
        Ok(ch)
    };
    let mut coordinator = {
        let mut c = std::process::Command::new(&exe);
        c.arg("run").args(&run_args).args([
            "--transport",
            "tcp",
            "--listen-addr",
            &addr,
            "--workers",
            &workers.to_string(),
        ]);
        match c.spawn() {
            Ok(ch) => ch,
            Err(e) => {
                eprintln!("cannot spawn the coordinator: {e}");
                return ExitCode::FAILURE;
            }
        }
    };
    eprintln!(
        "fedgraph launch: coordinator is pid {} on {addr}; spawning {workers} worker(s)",
        coordinator.id()
    );
    let mut kids: Vec<Option<std::process::Child>> = Vec::with_capacity(workers);
    for k in 0..workers {
        match spawn_worker(k) {
            Ok(ch) => kids.push(Some(ch)),
            Err(e) => {
                eprintln!("cannot spawn worker {k}: {e}");
                let _ = coordinator.kill();
                let _ = coordinator.wait();
                kill_workers(&mut kids);
                return ExitCode::FAILURE;
            }
        }
    }
    let mut restarts = 0usize;
    // Supervision loop: poll the fleet until the coordinator exits.
    let status = loop {
        std::thread::sleep(std::time::Duration::from_millis(100));
        match coordinator.try_wait() {
            Ok(Some(status)) => break status,
            Ok(None) => {}
            Err(e) => {
                eprintln!("cannot poll the coordinator: {e}");
                let _ = coordinator.kill();
                let _ = coordinator.wait();
                kill_workers(&mut kids);
                return ExitCode::FAILURE;
            }
        }
        for (k, slot) in kids.iter_mut().enumerate() {
            let exited = match slot {
                Some(ch) => matches!(ch.try_wait(), Ok(Some(_))),
                None => false,
            };
            if !exited {
                continue;
            }
            *slot = None;
            if restarts < max_restarts {
                restarts += 1;
                eprintln!(
                    "fedgraph launch: worker {k} exited mid-run; respawning as a standby \
                     (restart {restarts}/{max_restarts})"
                );
                match spawn_worker(k) {
                    Ok(ch) => *slot = Some(ch),
                    Err(e) => eprintln!("cannot respawn worker {k}: {e}"),
                }
            } else {
                eprintln!(
                    "fedgraph launch: worker {k} exited and the restart budget is spent; \
                     relying on coordinator-side recovery"
                );
            }
        }
    };
    // The coordinator's final Stop lets live workers drain and exit 0 on
    // their own; give them a grace period before force-killing stragglers
    // (e.g. a just-respawned standby still inside its connect backoff).
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    for slot in kids.iter_mut() {
        if let Some(ch) = slot {
            loop {
                match ch.try_wait() {
                    Ok(Some(_)) => break,
                    Ok(None) if std::time::Instant::now() < deadline => {
                        std::thread::sleep(std::time::Duration::from_millis(50))
                    }
                    _ => {
                        let _ = ch.kill();
                        let _ = ch.wait();
                        break;
                    }
                }
            }
        }
    }
    if status.success() {
        eprintln!("fedgraph launch: coordinator finished cleanly ({restarts} worker restart(s))");
        ExitCode::SUCCESS
    } else {
        eprintln!("fedgraph launch: coordinator exited with {status}");
        ExitCode::FAILURE
    }
}

fn kill_workers(kids: &mut Vec<Option<std::process::Child>>) {
    for slot in kids.iter_mut() {
        if let Some(ch) = slot {
            let _ = ch.kill();
            let _ = ch.wait();
        }
        *slot = None;
    }
}

/// Everything after `launch` that belongs to the child `run` command: the
/// supervisor's own flags — and the deployment flags it owns — removed.
fn passthrough_run_args(args: &[String]) -> Vec<String> {
    const OWNED: [&str; 5] =
        ["--workers", "--listen-addr", "--max-restarts", "--compose", "--transport"];
    let mut out = Vec::new();
    let mut i = 0;
    while i < args.len() {
        if OWNED.contains(&args[i].as_str()) {
            i += 2; // skip the flag and its value
        } else {
            out.push(args[i].clone());
            i += 1;
        }
    }
    out
}

/// `launch --compose <path>`: emit a compose-style manifest describing the
/// same deployment as services — one coordinator plus worker replicas — for
/// multi-machine runs where a single local supervisor cannot reach.
fn write_compose_manifest(
    path: &str,
    workers: usize,
    addr: &str,
    run_args: &[String],
) -> ExitCode {
    let run_line = run_args.join(" ");
    let sp = if run_line.is_empty() { "" } else { " " };
    let mut out = String::new();
    out.push_str("# Generated by `fedgraph launch --compose`.\n");
    out.push_str("# One coordinator plus worker replicas; point workers at the\n");
    out.push_str("# coordinator's address and spread the worker services across hosts.\n");
    out.push_str("# Worker restart policy mirrors the local supervisor: a dead worker\n");
    out.push_str("# comes back as a standby and is re-sliced in at a round boundary.\n");
    out.push_str("services:\n");
    out.push_str("  coordinator:\n");
    out.push_str(&format!(
        "    command: fedgraph run {run_line}{sp}--transport tcp --listen-addr {addr} \
         --workers {workers}\n"
    ));
    out.push_str("    restart: \"no\"\n");
    for k in 0..workers {
        out.push_str(&format!("  worker-{k}:\n"));
        out.push_str(&format!("    command: fedgraph worker --connect {addr}\n"));
        out.push_str("    restart: on-failure\n");
        out.push_str("    depends_on: [coordinator]\n");
    }
    match std::fs::write(path, out) {
        Ok(()) => {
            println!("compose manifest written to {path}");
            ExitCode::SUCCESS
        }
        Err(e) => {
            eprintln!("cannot write {path}: {e}");
            ExitCode::FAILURE
        }
    }
}

fn flag_value<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).map(|s| s.as_str())
}

fn has_flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

fn cmd_run(args: &[String]) -> ExitCode {
    let mut cfg = match build_config(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("config error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let trace_path = flag_value(args, "--trace");
    if trace_path.is_some() {
        // The flag rides the wire inside the config, so tcp workers see it
        // during their handshake and stream span buffers back.
        cfg.extras.insert("trace".to_string(), "1".to_string());
    }
    println!(
        "running {} / {} on {} ({} trainers, {} rounds)...",
        cfg.task.name(),
        cfg.method.name(),
        cfg.dataset,
        cfg.n_trainer,
        cfg.global_rounds
    );
    let outcome = if let Some(path) = trace_path {
        run_traced(&cfg, path)
    } else {
        fedgraph::run_fedgraph(&cfg)
    };
    match outcome {
        Ok(report) => {
            println!("{}", report.render());
            if let Some(path) = flag_value(args, "--json") {
                if let Err(e) = std::fs::write(path, report.to_json().to_string_pretty()) {
                    eprintln!("cannot write {path}: {e}");
                    return ExitCode::FAILURE;
                }
                println!("report written to {path}");
            }
            ExitCode::SUCCESS
        }
        Err(e) => {
            eprintln!("run failed: {e:#}");
            ExitCode::FAILURE
        }
    }
}

/// `run --trace <path>`: same run, with the flight recorder installed; the
/// merged coordinator + worker timeline is written to `path` as Chrome
/// trace-event JSON (open with Perfetto or chrome://tracing). Tracing is
/// pure observation — the report is bitwise-identical to an untraced run.
fn run_traced(cfg: &FedGraphConfig, path: &str) -> anyhow::Result<fedgraph::Report> {
    let engine = fedgraph::runtime::Engine::start(&cfg.artifacts_dir)?;
    let result = fedgraph::coordinator::run_fedgraph_traced(cfg, &engine);
    engine.shutdown();
    let (report, trace_json) = result?;
    std::fs::write(path, trace_json)
        .map_err(|e| anyhow::anyhow!("cannot write trace to {path}: {e}"))?;
    println!("trace written to {path} (load in Perfetto / chrome://tracing)");
    Ok(report)
}

fn build_config(args: &[String]) -> anyhow::Result<FedGraphConfig> {
    let mut cfg = if let Some(path) = flag_value(args, "--config") {
        FedGraphConfig::from_yaml_file(path)?
    } else {
        let task = Task::parse(flag_value(args, "--task").unwrap_or("NC"))?;
        let method = Method::parse(task, flag_value(args, "--method").unwrap_or("FedGCN"))?;
        let dataset = flag_value(args, "--dataset").unwrap_or("cora-sim");
        FedGraphConfig::new(task, method, dataset)?
    };
    if let Some(v) = flag_value(args, "--rounds") {
        cfg.global_rounds = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--trainers") {
        cfg.n_trainer = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--local-steps") {
        cfg.local_steps = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--lr") {
        cfg.learning_rate = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--scale") {
        cfg.scale = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--beta") {
        cfg.iid_beta = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--batch-size") {
        cfg.batch_size = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--lowrank") {
        cfg.lowrank_rank = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--hops") {
        cfg.num_hops = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--sample-ratio") {
        cfg.sample_ratio = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--seed") {
        cfg.seed = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--dataset-format") {
        cfg.dataset_format = DatasetFormat::parse(v)?;
    }
    if let Some(v) = flag_value(args, "--concurrency") {
        cfg.federation.max_concurrency = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--dropout") {
        cfg.federation.dropout_frac = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--straggler-ms") {
        cfg.federation.straggler_ms = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--mode") {
        cfg.federation.mode = FederationMode::parse(v)?;
    }
    if let Some(v) = flag_value(args, "--max-staleness") {
        cfg.federation.max_staleness = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--buffer-size") {
        cfg.federation.buffer_size = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--agg-shards") {
        cfg.federation.agg_shards = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--heartbeat-ms") {
        cfg.federation.fault_tolerance.heartbeat_ms = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--worker-timeout-ms") {
        cfg.federation.fault_tolerance.worker_timeout_ms = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--checkpoint-every") {
        cfg.federation.fault_tolerance.checkpoint_every = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--checkpoint-dir") {
        cfg.federation.fault_tolerance.checkpoint_dir = v.to_string();
    }
    if let Some(v) = flag_value(args, "--reconnect-grace-ms") {
        cfg.federation.fault_tolerance.reconnect_grace_ms = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--resume") {
        cfg.extras.insert("resume".to_string(), v.to_string());
    }
    if let Some(v) = flag_value(args, "--transport") {
        cfg.federation.transport = TransportKind::parse(v)?;
    }
    if let Some(v) = flag_value(args, "--listen-addr") {
        cfg.federation.listen_addr = v.to_string();
    }
    if let Some(v) = flag_value(args, "--workers") {
        cfg.federation.workers = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--compression") {
        cfg.federation.compression = CompressionMode::parse(v)?;
    }
    if let Some(v) = flag_value(args, "--entropy") {
        cfg.federation.entropy = EntropyMode::parse(v)?;
    }
    if let CompressionMode::Quantized { mut bits, mut error_feedback } =
        cfg.federation.compression
    {
        if let Some(v) = flag_value(args, "--quantized-bits") {
            bits = v.parse()?;
        }
        if has_flag(args, "--no-error-feedback") {
            error_feedback = false;
        }
        cfg.federation.compression = CompressionMode::Quantized { bits, error_feedback };
    }
    if has_flag(args, "--he") {
        cfg.privacy = PrivacyMode::He(CkksParams::default_params());
    }
    if has_flag(args, "--dp") {
        cfg.privacy =
            PrivacyMode::Dp(fedgraph::config::DpClone(DpParams::default_params()));
    }
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_list() -> ExitCode {
    println!("tasks / methods (paper Table 5):");
    println!("  NC: FedAvg, DistributedGCN, BNS-GCN, FedSage+, FedGCN");
    println!("  GC: SelfTrain, FedAvg, FedProx, GCFL, GCFL+, GCFL+dWs");
    println!("  LP: StaticGNN, STFL, FedLink, 4D-FED-GNN+");
    println!("\ndatasets (synthetic, statistics-matched — Table 4):");
    for s in data::nc_specs() {
        println!(
            "  NC {:<16} n={:<7} d={:<5} classes={}",
            s.name, s.n, s.feat_dim, s.num_classes
        );
    }
    println!("  NC papers100m-sim  n=1e8 (lazy) d=128  classes=172");
    for s in data::gc_specs() {
        println!(
            "  GC {:<16} graphs={:<5} avg_nodes={:<5} classes={}",
            s.name, s.num_graphs, s.avg_nodes, s.num_classes
        );
    }
    println!("  LP US | US+BR | 5country   (foursquare-sim check-in regions)");
    ExitCode::SUCCESS
}

fn cmd_artifacts(args: &[String]) -> ExitCode {
    let dir = flag_value(args, "--dir")
        .map(|s| s.to_string())
        .unwrap_or_else(fedgraph::config::default_artifacts_dir);
    match fedgraph::runtime::Manifest::load(&dir) {
        Ok(m) => {
            println!("{} artifacts in {}/ (hidden={})", m.artifacts.len(), dir, m.hidden);
            for a in m.artifacts.values() {
                println!("  {:<36} kind={:<14} dims={:?}", a.name, a.kind, a.dims);
            }
            ExitCode::SUCCESS
        }
        Err(e) => {
            eprintln!("{e:#}");
            ExitCode::FAILURE
        }
    }
}
