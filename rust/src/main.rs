//! `fedgraph` CLI — the launcher (hand-rolled argument parsing; clap is not
//! available offline).
//!
//! Usage:
//!   fedgraph run --config configs/cora_fedgcn.yaml [--json out.json]
//!   fedgraph run --task NC --dataset cora-sim --method FedGCN [--rounds N]
//!               [--trainers M] [--scale S] [--he] [--dp] [--lowrank K]
//!               [--transport channel|tcp --listen-addr H:P --workers W]
//!   fedgraph worker --connect <host:port>   # host trainer actors for a
//!                                           # tcp-transport coordinator
//!   fedgraph list                 # supported task/method/dataset matrix
//!   fedgraph artifacts            # show the loaded artifact manifest

use std::process::ExitCode;

use fedgraph::config::{
    CompressionMode, DatasetFormat, EntropyMode, FedGraphConfig, FederationMode, Method,
    PrivacyMode, Task, TransportKind,
};
use fedgraph::data;
use fedgraph::he::{CkksParams, DpParams};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        Some("run") => cmd_run(&args[1..]),
        Some("worker") => cmd_worker(&args[1..]),
        Some("list") => cmd_list(),
        Some("artifacts") => cmd_artifacts(&args[1..]),
        Some("--help") | Some("-h") | None => {
            print_help();
            ExitCode::SUCCESS
        }
        Some(other) => {
            eprintln!("unknown command '{other}'\n");
            print_help();
            ExitCode::FAILURE
        }
    }
}

fn print_help() {
    println!(
        "fedgraph — federated graph learning benchmark (FedGraph reproduction)\n\n\
         commands:\n\
         \x20 run --config <file.yaml> [--json <out.json>] [--trace <out.trace.json>]\n\
         \x20 run --task NC|GC|LP --dataset <name> --method <name>\n\
         \x20     [--rounds N] [--trainers M] [--local-steps K] [--lr F]\n\
         \x20     [--scale S] [--beta B] [--batch-size B] [--he] [--dp]\n\
         \x20     [--lowrank K] [--hops H] [--sample-ratio R] [--seed S]\n\
         \x20     [--dataset-format v1|v2]\n\
         \x20     [--concurrency K] [--dropout F] [--straggler-ms MS]\n\
         \x20     [--mode sync|async] [--max-staleness N] [--buffer-size N]\n\
         \x20     [--agg-shards N]\n\
         \x20     [--heartbeat-ms MS] [--worker-timeout-ms MS]\n\
         \x20     [--checkpoint-every N]\n\
         \x20     [--transport channel|tcp] [--listen-addr HOST:PORT]\n\
         \x20     [--workers W]\n\
         \x20     [--compression none|pack|quantized] [--quantized-bits 4|8]\n\
         \x20     [--entropy none|rans] [--no-error-feedback]\n\
         \x20     [--trace <out.trace.json>]\n\
         \x20     --trace records a cross-process span timeline (coordinator,\n\
         \x20     trainer actors, codec, sockets, workers) and writes Chrome\n\
         \x20     trace-event JSON loadable in Perfetto; the run itself is\n\
         \x20     bitwise-identical to an untraced one\n\
         \x20     --dataset-format v1 (default) keeps the sequential-stream\n\
         \x20     generators; v2 switches to counter-based keyed generation\n\
         \x20     so each worker generates only its assigned slice\n\
         \x20     (O(assigned nodes) startup work and memory). The two\n\
         \x20     formats are statistically matched but bitwise different.\n\
         \x20     --compression pack is lossless and bitwise-identical to\n\
         \x20     none in both directions (only measured wire bytes shrink);\n\
         \x20     quantized is a lossy int8/int4 upload-delta codec\n\
         \x20     (plaintext/DP only); --entropy rans adds a lossless rANS\n\
         \x20     entropy stage behind the pack codec\n\
         \x20     With --transport tcp the run waits for W `fedgraph worker`\n\
         \x20     processes to connect; results are bitwise-identical to the\n\
         \x20     in-process channel transport for the same config/seed.\n\
         \x20     --heartbeat-ms / --worker-timeout-ms tune tcp liveness\n\
         \x20     detection (timeout 0 disables it); a crashed worker's\n\
         \x20     clients are re-assigned to survivors and the round resumes\n\
         \x20     (sync runs stay bitwise-identical). --checkpoint-every N\n\
         \x20     snapshots coordinator state every N rounds (0 = off); see\n\
         \x20     docs/FAULT_TOLERANCE.md.\n\
         \x20 worker --connect <host:port> [--artifacts DIR] [--timeout-secs S]\n\
         \x20     host trainer actors for a tcp-transport coordinator: the\n\
         \x20     worker receives its client assignment + config over the\n\
         \x20     socket, rebuilds the session deterministically, and exits 0\n\
         \x20     when the coordinator finishes the run\n\
         \x20 list       supported task/method/dataset matrix\n\
         \x20 artifacts  show the artifact manifest"
    );
}

fn cmd_worker(args: &[String]) -> ExitCode {
    let Some(addr) = flag_value(args, "--connect") else {
        eprintln!("worker needs --connect <host:port> (the coordinator's listen_addr)");
        return ExitCode::FAILURE;
    };
    let artifacts = flag_value(args, "--artifacts");
    let timeout_secs: u64 = flag_value(args, "--timeout-secs")
        .and_then(|v| v.parse().ok())
        .unwrap_or(30);
    match fedgraph::federation::worker::run_worker(
        addr,
        artifacts,
        std::time::Duration::from_secs(timeout_secs),
    ) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("worker failed: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn flag_value<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).map(|s| s.as_str())
}

fn has_flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

fn cmd_run(args: &[String]) -> ExitCode {
    let mut cfg = match build_config(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("config error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let trace_path = flag_value(args, "--trace");
    if trace_path.is_some() {
        // The flag rides the wire inside the config, so tcp workers see it
        // during their handshake and stream span buffers back.
        cfg.extras.insert("trace".to_string(), "1".to_string());
    }
    println!(
        "running {} / {} on {} ({} trainers, {} rounds)...",
        cfg.task.name(),
        cfg.method.name(),
        cfg.dataset,
        cfg.n_trainer,
        cfg.global_rounds
    );
    let outcome = if let Some(path) = trace_path {
        run_traced(&cfg, path)
    } else {
        fedgraph::run_fedgraph(&cfg)
    };
    match outcome {
        Ok(report) => {
            println!("{}", report.render());
            if let Some(path) = flag_value(args, "--json") {
                if let Err(e) = std::fs::write(path, report.to_json().to_string_pretty()) {
                    eprintln!("cannot write {path}: {e}");
                    return ExitCode::FAILURE;
                }
                println!("report written to {path}");
            }
            ExitCode::SUCCESS
        }
        Err(e) => {
            eprintln!("run failed: {e:#}");
            ExitCode::FAILURE
        }
    }
}

/// `run --trace <path>`: same run, with the flight recorder installed; the
/// merged coordinator + worker timeline is written to `path` as Chrome
/// trace-event JSON (open with Perfetto or chrome://tracing). Tracing is
/// pure observation — the report is bitwise-identical to an untraced run.
fn run_traced(cfg: &FedGraphConfig, path: &str) -> anyhow::Result<fedgraph::Report> {
    let engine = fedgraph::runtime::Engine::start(&cfg.artifacts_dir)?;
    let result = fedgraph::coordinator::run_fedgraph_traced(cfg, &engine);
    engine.shutdown();
    let (report, trace_json) = result?;
    std::fs::write(path, trace_json)
        .map_err(|e| anyhow::anyhow!("cannot write trace to {path}: {e}"))?;
    println!("trace written to {path} (load in Perfetto / chrome://tracing)");
    Ok(report)
}

fn build_config(args: &[String]) -> anyhow::Result<FedGraphConfig> {
    let mut cfg = if let Some(path) = flag_value(args, "--config") {
        FedGraphConfig::from_yaml_file(path)?
    } else {
        let task = Task::parse(flag_value(args, "--task").unwrap_or("NC"))?;
        let method = Method::parse(task, flag_value(args, "--method").unwrap_or("FedGCN"))?;
        let dataset = flag_value(args, "--dataset").unwrap_or("cora-sim");
        FedGraphConfig::new(task, method, dataset)?
    };
    if let Some(v) = flag_value(args, "--rounds") {
        cfg.global_rounds = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--trainers") {
        cfg.n_trainer = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--local-steps") {
        cfg.local_steps = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--lr") {
        cfg.learning_rate = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--scale") {
        cfg.scale = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--beta") {
        cfg.iid_beta = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--batch-size") {
        cfg.batch_size = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--lowrank") {
        cfg.lowrank_rank = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--hops") {
        cfg.num_hops = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--sample-ratio") {
        cfg.sample_ratio = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--seed") {
        cfg.seed = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--dataset-format") {
        cfg.dataset_format = DatasetFormat::parse(v)?;
    }
    if let Some(v) = flag_value(args, "--concurrency") {
        cfg.federation.max_concurrency = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--dropout") {
        cfg.federation.dropout_frac = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--straggler-ms") {
        cfg.federation.straggler_ms = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--mode") {
        cfg.federation.mode = FederationMode::parse(v)?;
    }
    if let Some(v) = flag_value(args, "--max-staleness") {
        cfg.federation.max_staleness = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--buffer-size") {
        cfg.federation.buffer_size = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--agg-shards") {
        cfg.federation.agg_shards = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--heartbeat-ms") {
        cfg.federation.fault_tolerance.heartbeat_ms = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--worker-timeout-ms") {
        cfg.federation.fault_tolerance.worker_timeout_ms = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--checkpoint-every") {
        cfg.federation.fault_tolerance.checkpoint_every = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--transport") {
        cfg.federation.transport = TransportKind::parse(v)?;
    }
    if let Some(v) = flag_value(args, "--listen-addr") {
        cfg.federation.listen_addr = v.to_string();
    }
    if let Some(v) = flag_value(args, "--workers") {
        cfg.federation.workers = v.parse()?;
    }
    if let Some(v) = flag_value(args, "--compression") {
        cfg.federation.compression = CompressionMode::parse(v)?;
    }
    if let Some(v) = flag_value(args, "--entropy") {
        cfg.federation.entropy = EntropyMode::parse(v)?;
    }
    if let CompressionMode::Quantized { mut bits, mut error_feedback } =
        cfg.federation.compression
    {
        if let Some(v) = flag_value(args, "--quantized-bits") {
            bits = v.parse()?;
        }
        if has_flag(args, "--no-error-feedback") {
            error_feedback = false;
        }
        cfg.federation.compression = CompressionMode::Quantized { bits, error_feedback };
    }
    if has_flag(args, "--he") {
        cfg.privacy = PrivacyMode::He(CkksParams::default_params());
    }
    if has_flag(args, "--dp") {
        cfg.privacy =
            PrivacyMode::Dp(fedgraph::config::DpClone(DpParams::default_params()));
    }
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_list() -> ExitCode {
    println!("tasks / methods (paper Table 5):");
    println!("  NC: FedAvg, DistributedGCN, BNS-GCN, FedSage+, FedGCN");
    println!("  GC: SelfTrain, FedAvg, FedProx, GCFL, GCFL+, GCFL+dWs");
    println!("  LP: StaticGNN, STFL, FedLink, 4D-FED-GNN+");
    println!("\ndatasets (synthetic, statistics-matched — Table 4):");
    for s in data::nc_specs() {
        println!(
            "  NC {:<16} n={:<7} d={:<5} classes={}",
            s.name, s.n, s.feat_dim, s.num_classes
        );
    }
    println!("  NC papers100m-sim  n=1e8 (lazy) d=128  classes=172");
    for s in data::gc_specs() {
        println!(
            "  GC {:<16} graphs={:<5} avg_nodes={:<5} classes={}",
            s.name, s.num_graphs, s.avg_nodes, s.num_classes
        );
    }
    println!("  LP US | US+BR | 5country   (foursquare-sim check-in regions)");
    ExitCode::SUCCESS
}

fn cmd_artifacts(args: &[String]) -> ExitCode {
    let dir = flag_value(args, "--dir")
        .map(|s| s.to_string())
        .unwrap_or_else(fedgraph::config::default_artifacts_dir);
    match fedgraph::runtime::Manifest::load(&dir) {
        Ok(m) => {
            println!("{} artifacts in {}/ (hidden={})", m.artifacts.len(), dir, m.hidden);
            for a in m.artifacts.values() {
                println!("  {:<36} kind={:<14} dims={:?}", a.name, a.kind, a.dims);
            }
            ExitCode::SUCCESS
        }
        Err(e) => {
            eprintln!("{e:#}");
            ExitCode::FAILURE
        }
    }
}
