"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle,
hypothesis-swept over shapes and dtypes (the CORE correctness signal)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gin as gin_kernel
from compile.kernels import lowrank
from compile.kernels import matmul as mm
from compile.kernels import ref

DIMS = st.integers(min_value=1, max_value=97)


def rand(rs, shape, dtype):
    x = rs.randn(*shape) * 2.0
    return x.astype(dtype)


@settings(max_examples=25, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref(m, k, n, seed):
    rs = np.random.RandomState(seed)
    x = rand(rs, (m, k), np.float32)
    w = rand(rs, (k, n), np.float32)
    got = mm.matmul(x, w)
    want = ref.matmul_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    m=DIMS,
    k=DIMS,
    n=DIMS,
    relu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_linear_matches_ref(m, k, n, relu, seed):
    rs = np.random.RandomState(seed)
    x = rand(rs, (m, k), np.float32)
    w = rand(rs, (k, n), np.float32)
    b = rand(rs, (n,), np.float32)
    got = mm.fused_linear(x, w, b, relu=relu)
    want = ref.fused_linear_ref(x, w, b, relu=relu)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
def test_matmul_bf16_inputs(m, k, n, seed):
    """bf16 inputs accumulate in f32 (the MXU convention)."""
    rs = np.random.RandomState(seed)
    x = rand(rs, (m, k), np.float32)
    w = rand(rs, (k, n), np.float32)
    got = mm.matmul(jnp.asarray(x, jnp.bfloat16), jnp.asarray(w, jnp.bfloat16))
    want = ref.matmul_ref(
        jnp.asarray(x, jnp.bfloat16).astype(jnp.float32),
        jnp.asarray(w, jnp.bfloat16).astype(jnp.float32),
    )
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(got, want, rtol=1e-2, atol=1e-2)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 600),
    d=DIMS,
    eps=st.floats(-0.5, 2.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_gin_combine_matches_ref(m, d, eps, seed):
    rs = np.random.RandomState(seed)
    x = rand(rs, (m, d), np.float32)
    agg = rand(rs, (m, d), np.float32)
    got = gin_kernel.gin_combine(x, agg, eps=eps)
    want = ref.gin_combine_ref(x, agg, eps)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 80), d=st.integers(8, 200), k=st.integers(1, 64), seed=st.integers(0, 2**31 - 1))
def test_lowrank_projection_matches_ref(n, d, k, seed):
    rs = np.random.RandomState(seed)
    x = rand(rs, (n, d), np.float32)
    p = (rs.randn(d, k) / np.sqrt(k)).astype(np.float32)
    got = lowrank.project(x, p)
    want = ref.matmul_ref(x, p)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_tile_boundary_shapes():
    """Exact multiples and off-by-one around the 128 tile boundary."""
    rs = np.random.RandomState(0)
    for m in (127, 128, 129):
        for k in (255, 256, 257):
            x = rand(rs, (m, k), np.float32)
            w = rand(rs, (k, 64), np.float32)
            np.testing.assert_allclose(
                mm.matmul(x, w), ref.matmul_ref(x, w), rtol=1e-4, atol=1e-4
            )


def test_custom_tile_sizes():
    rs = np.random.RandomState(1)
    x = rand(rs, (70, 300), np.float32)
    w = rand(rs, (300, 40), np.float32)
    got = mm.matmul(x, w, bm=32, bn=16, bk=64)
    np.testing.assert_allclose(got, ref.matmul_ref(x, w), rtol=1e-4, atol=1e-4)


def test_vmem_budget_documented():
    """The default tiles must fit a 16 MiB VMEM budget (DESIGN.md #Perf)."""
    assert mm.vmem_bytes() <= 16 * 1024 * 1024
    assert lowrank.vmem_bytes() <= 16 * 1024 * 1024


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(2, 50),
    d=st.integers(1, 16),
    e=st.integers(1, 200),
    seed=st.integers(0, 2**31 - 1),
)
def test_segment_aggregate_pad_arc_convention(n, d, e, seed):
    """Pad arcs (weight 0, sink endpoints) never change the aggregation."""
    from compile.model import segment_aggregate

    rs = np.random.RandomState(seed)
    x = rand(rs, (n, d), np.float32)
    src = rs.randint(0, n, e).astype(np.int32)
    dst = rs.randint(0, n, e).astype(np.int32)
    w = rs.rand(e).astype(np.float32)
    base = segment_aggregate(x, src, dst, w)
    # Append pad arcs.
    pad = 37
    src2 = np.concatenate([src, np.full(pad, n - 1, np.int32)])
    dst2 = np.concatenate([dst, np.full(pad, n - 1, np.int32)])
    w2 = np.concatenate([w, np.zeros(pad, np.float32)])
    with_pads = segment_aggregate(x, src2, dst2, w2)
    np.testing.assert_allclose(base, with_pads, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(
        base, ref.segment_aggregate_ref(x, src, dst, w, n), rtol=1e-5, atol=1e-5
    )


def test_matmul_rejects_bad_shapes():
    with pytest.raises(AssertionError):
        mm.matmul(np.zeros((2, 3), np.float32), np.zeros((4, 5), np.float32))
