"""L2 model tests: forward/backward correctness against hand-rolled jnp,
training dynamics, and the masking conventions the Rust blocks rely on."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model


def _nc_inputs(rs, n=24, e=60, d=10, c=4, h=64):
    params = (
        (rs.randn(d, h) * 0.2).astype(np.float32),
        np.zeros(h, np.float32),
        (rs.randn(h, c) * 0.2).astype(np.float32),
        np.zeros(c, np.float32),
    )
    x = rs.randn(n, d).astype(np.float32)
    src = rs.randint(0, n, e).astype(np.int32)
    dst = rs.randint(0, n, e).astype(np.int32)
    enorm = rs.rand(e).astype(np.float32)
    labels = rs.randint(0, c, n).astype(np.int32)
    mask = (rs.rand(n) < 0.7).astype(np.float32)
    return params, (x, src, dst, enorm, labels, mask)


def test_gcn_forward_matches_manual():
    rs = np.random.RandomState(0)
    params, (x, src, dst, enorm, labels, mask) = _nc_inputs(rs)
    w1, b1, w2, b2 = params
    logits = model.gcn2_logits(params, x, src, dst, enorm)
    # Manual: agg(x@w1)+b1, relu, agg(h@w2)+b2 with explicit scatter.
    n = x.shape[0]

    def agg(t):
        out = np.zeros_like(t)
        for k in range(len(src)):
            out[dst[k]] += enorm[k] * t[src[k]]
        return out

    h = np.maximum(agg(x @ w1) + b1, 0.0)
    want = agg(h @ w2) + b2
    np.testing.assert_allclose(np.array(logits), want, rtol=1e-3, atol=1e-3)


def test_masked_ce_matches_manual():
    rs = np.random.RandomState(1)
    logits = rs.randn(10, 5).astype(np.float32)
    labels = rs.randint(0, 5, 10).astype(np.int32)
    mask = np.array([1, 0, 1, 1, 0, 0, 1, 0, 0, 1], np.float32)
    loss, correct, cnt = model.masked_ce(jnp.asarray(logits), jnp.asarray(labels), jnp.asarray(mask))
    # manual
    z = logits - logits.max(axis=1, keepdims=True)
    logp = z - np.log(np.exp(z).sum(axis=1, keepdims=True))
    nll = -logp[np.arange(10), labels]
    want_loss = (mask * nll).sum() / mask.sum()
    want_correct = (mask * (logits.argmax(1) == labels)).sum()
    assert abs(float(loss) - want_loss) < 1e-5
    assert float(correct) == want_correct
    assert float(cnt) == mask.sum()


def test_nc_gradients_match_finite_differences():
    rs = np.random.RandomState(2)
    params, data = _nc_inputs(rs, n=12, e=30, d=6, c=3, h=8)

    def loss_of(params):
        loss, _aux = model.nc_loss(params, *data)
        return loss

    grads = jax.grad(loss_of)(params)
    # Check a few coordinates of w1 by central differences.
    w1 = params[0]
    for idx in [(0, 0), (3, 5), (5, 2)]:
        epsv = 1e-3
        wp = w1.copy()
        wp[idx] += epsv
        wm = w1.copy()
        wm[idx] -= epsv
        lp = float(loss_of((wp, *params[1:])))
        lm = float(loss_of((wm, *params[1:])))
        fd = (lp - lm) / (2 * epsv)
        ad = float(grads[0][idx])
        assert abs(fd - ad) < 5e-2 * (1 + abs(fd)), f"{idx}: fd {fd} vs ad {ad}"


def test_nc_train_reduces_loss():
    rs = np.random.RandomState(3)
    params, data = _nc_inputs(rs, n=40, e=100, d=8, c=3)
    # Plant separable signal.
    x, src, dst, enorm, labels, mask = data
    x = np.zeros_like(x)
    for i in range(len(labels)):
        x[i, labels[i]] = 2.0
    data = (x, src, dst, enorm, labels, np.ones_like(mask))
    losses = []
    p = params
    for _ in range(30):
        out = model.nc_train_step(*p, *data, jnp.float32(0.5))
        p = tuple(np.array(t) for t in out[:4])
        losses.append(float(out[4]))
    # The random-edge aggregation mixes classes, so the floor is above zero;
    # requiring a 35% reduction checks the optimizer without overfitting the
    # synthetic construction.
    assert losses[-1] < losses[0] * 0.65, losses


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_eval_step_is_pure(seed):
    """Eval never mutates params and equals the loss part of train output."""
    rs = np.random.RandomState(seed)
    params, data = _nc_inputs(rs)
    ev = model.nc_eval_step(*params, *data)
    tr = model.nc_train_step(*params, *data, jnp.float32(0.0))
    # lr=0: returned params identical, loss matches eval.
    for p_in, p_out in zip(params, tr[:4]):
        np.testing.assert_allclose(np.array(p_out), p_in, rtol=1e-6, atol=1e-6)
    assert abs(float(ev[0]) - float(tr[4])) < 1e-6


def test_fedprox_mu_zero_equals_fedavg_step():
    rs = np.random.RandomState(4)
    n, e, d, c, h, g = 30, 80, 8, 4, 64, 6
    params = tuple(
        (rs.randn(*s) * 0.2).astype(np.float32) if len(s) == 2 else np.zeros(s, np.float32)
        for s in [(d, h), (h,), (h, h), (h,), (h, c), (c,)]
    )
    x = rs.randn(n, d).astype(np.float32)
    src = rs.randint(0, n, e).astype(np.int32)
    dst = rs.randint(0, n, e).astype(np.int32)
    enorm = np.ones(e, np.float32)
    gid = rs.randint(0, g, n).astype(np.int32)
    nmask = np.ones(n, np.float32)
    glabels = rs.randint(0, c, g).astype(np.int32)
    gmask = np.ones(g, np.float32)
    data = (x, src, dst, enorm, gid, nmask, glabels, gmask)
    plain = model.gc_train_step(*params, *data, jnp.float32(0.2))
    prox0 = model.gc_prox_train_step(*params, *params, *data, jnp.float32(0.2), jnp.float32(0.0))
    for a, b in zip(plain[:6], prox0[:6]):
        np.testing.assert_allclose(np.array(a), np.array(b), rtol=1e-5, atol=1e-6)
    # And a positive mu pulls the step towards the anchor (smaller move).
    prox1 = model.gc_prox_train_step(*params, *params, *data, jnp.float32(0.2), jnp.float32(10.0))
    move = lambda out: sum(
        float(np.abs(np.array(o) - p).sum()) for o, p in zip(out[:6], params)
    )
    assert move(prox1) <= move(plain) + 1e-4


def test_lp_training_separates_pos_from_neg():
    rs = np.random.RandomState(5)
    n, e, d, h, p = 40, 120, 8, 64, 30
    params = (
        (rs.randn(d, h) * 0.3).astype(np.float32),
        np.zeros(h, np.float32),
        (rs.randn(h, 32) * 0.3).astype(np.float32),
        np.zeros(32, np.float32),
    )
    # Two communities with distinct features; positives inside, negatives across.
    x = np.zeros((n, d), np.float32)
    x[: n // 2, 0] = 1.0
    x[n // 2 :, 1] = 1.0
    # Random arcs plus a self-loop per node (the Rust blocks always include
    # GCN self-loops — without them isolated nodes get zero embeddings and
    # zero gradients).
    src = np.concatenate([rs.randint(0, n, e), np.arange(n)]).astype(np.int32)
    dst = np.concatenate([rs.randint(0, n, e), np.arange(n)]).astype(np.int32)
    enorm = np.concatenate([np.ones(e) * 0.1, np.ones(n) * 0.5]).astype(np.float32)
    pos_u = rs.randint(0, n // 2, p).astype(np.int32)
    pos_v = rs.randint(0, n // 2, p).astype(np.int32)
    neg_u = rs.randint(0, n // 2, p).astype(np.int32)
    neg_v = (rs.randint(n // 2, n, p)).astype(np.int32)
    pmask = np.ones(p, np.float32)
    pr = params
    first = None
    for _ in range(30):
        out = model.lp_train_step(*pr, x, src, dst, enorm, pos_u, pos_v, neg_u, neg_v, pmask, jnp.float32(0.3))
        pr = tuple(np.array(t) for t in out[:4])
        if first is None:
            first = float(out[4])
    assert float(out[4]) < first * 0.8
    scores = model.lp_score_step(*pr, x, src, dst, enorm, pos_u, pos_v)[0]
    neg_scores = model.lp_score_step(*pr, x, src, dst, enorm, neg_u, neg_v)[0]
    assert float(jnp.mean(scores)) > float(jnp.mean(neg_scores))


def test_gc_mean_readout_is_size_invariant():
    """Two identical-structure graphs of different sizes pool to the same
    logits under the mean readout."""
    rs = np.random.RandomState(6)
    d, c, h = 8, 4, 64
    params = tuple(
        (rs.randn(*s) * 0.2).astype(np.float32) if len(s) == 2 else np.zeros(s, np.float32)
        for s in [(d, h), (h,), (h, h), (h,), (h, c), (c,)]
    )
    feat = rs.randn(1, d).astype(np.float32)

    def batch(copies):
        n = copies
        x = np.repeat(feat, n, axis=0)
        src = np.zeros(0, np.int32)
        dst = np.zeros(0, np.int32)
        enorm = np.zeros(0, np.float32)
        gid = np.zeros(n, np.int32)
        nmask = np.ones(n, np.float32)
        glabels = np.zeros(1, np.int32)
        gmask = np.ones(1, np.float32)
        return model.gin_logits(params, x, src, dst, enorm, gid, nmask, 1)

    l1 = np.array(batch(2))
    l2 = np.array(batch(7))
    np.testing.assert_allclose(l1, l2, rtol=1e-4, atol=1e-4)
