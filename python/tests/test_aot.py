"""AOT export tests: manifest consistency and HLO-text emission."""

import json
import os

from compile import aot, manifest


def test_manifest_is_consistent():
    arts = manifest.build_artifacts()
    names = [a["name"] for a in arts]
    assert len(names) == len(set(names))
    kinds = {a["kind"] for a in arts}
    assert kinds == {
        "nc_train",
        "nc_eval",
        "nc_train_pallas",
        "nc_eval_pallas",
        "gc_train",
        "gc_prox_train",
        "gc_eval",
        "lp_train",
        "lp_eval",
    }
    for a in arts:
        # Train artifacts return the updated params first, so outputs must be
        # longer than eval metrics alone.
        if a["kind"].endswith("train"):
            assert a["inputs"][-1]["name"] in ("lr", "mu")
        # every input/output spec has shape + dtype
        for io in a["inputs"] + a["outputs"]:
            assert io["dtype"] in ("f32", "i32")
            assert isinstance(io["shape"], list)
        # edge bucket follows the documented factor
        if "e" in a["dims"]:
            assert a["dims"]["e"] == manifest.EDGE_FACTOR * a["dims"]["n"]


def test_every_nc_dataset_has_buckets():
    arts = manifest.build_artifacts()
    for _tag, d, c, buckets in manifest.NC_DATASETS:
        for n in buckets:
            name = f"nc_train_d{d}_c{c}_n{n}"
            assert any(a["name"] == name for a in arts), name


def test_lowering_emits_hlo_text():
    art = next(
        a for a in manifest.build_artifacts() if a["name"] == "nc_eval_d100_c7_n256"
    )
    text = aot.lower_artifact(art)
    assert "HloModule" in text
    assert "ENTRY" in text
    # tuple-rooted (return_tuple=True) with one element per declared output
    assert len(text) > 1000


def test_pallas_and_reference_lowerings_agree():
    """The pallas-backend artifact must compute the same function as the
    reference artifact (same bucket, same inputs)."""
    import numpy as np
    import jax

    arts = {a["name"]: a for a in manifest.build_artifacts()}
    ref_art = arts["nc_eval_d100_c7_n256"]
    pal_art = arts["nc_eval_pallas_d100_c7_n256"]
    assert ref_art["dims"] == pal_art["dims"]

    from compile import model

    rs = np.random.RandomState(0)
    n, e, d, c, h = 256, 4096, 100, 7, manifest.HIDDEN
    args = (
        (rs.randn(d, h) * 0.2).astype(np.float32),
        np.zeros(h, np.float32),
        (rs.randn(h, c) * 0.2).astype(np.float32),
        np.zeros(c, np.float32),
        rs.randn(n, d).astype(np.float32),
        rs.randint(0, n, e).astype(np.int32),
        rs.randint(0, n, e).astype(np.int32),
        rs.rand(e).astype(np.float32),
        rs.randint(0, c, n).astype(np.int32),
        np.ones(n, np.float32),
    )
    model.set_backend("reference")
    ref_out = jax.jit(model.nc_eval_step)(*args)
    model.set_backend("pallas")
    try:
        pal_out = jax.jit(model.nc_eval_step)(*args)
    finally:
        model.set_backend("reference")
    for a, b in zip(ref_out, pal_out):
        np.testing.assert_allclose(np.array(a), np.array(b), rtol=1e-4, atol=1e-4)


def test_written_manifest_matches_disk(tmp_path=None):
    """When `make artifacts` has run, manifest.json must agree with the
    in-tree manifest.py and every referenced file must exist."""
    out = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    man_path = os.path.join(out, "manifest.json")
    if not os.path.exists(man_path):
        import pytest

        pytest.skip("artifacts not built")
    with open(man_path) as f:
        man = json.load(f)
    arts = {a["name"]: a for a in manifest.build_artifacts()}
    assert set(man["artifacts"].keys()) == set(arts.keys())
    for name, entry in man["artifacts"].items():
        assert os.path.exists(os.path.join(out, entry["file"])), name
        assert entry["dims"] == arts[name]["dims"]
