"""Pure-jnp oracles for every Pallas kernel.

These are the CORE correctness signal: each kernel in this package must match
its oracle to float32 tolerance across the hypothesis shape/dtype sweeps in
python/tests/. The oracles are also used by the model tests to cross-check
the full forward/backward paths.
"""

import jax.numpy as jnp


def matmul_ref(x, w):
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))


def fused_linear_ref(x, w, b, relu=False):
    y = matmul_ref(x, w) + b.astype(jnp.float32)
    return jnp.maximum(y, 0.0) if relu else y


def gin_combine_ref(x, agg, eps):
    return (1.0 + eps) * x.astype(jnp.float32) + agg.astype(jnp.float32)


def segment_aggregate_ref(x, src, dst, enorm, n):
    """Weighted message aggregation: out[v] = sum_e 1[dst[e]=v] enorm[e] x[src[e]].

    This is the L2 (jnp) aggregation the models use; listed here because the
    kernel tests verify the padded-edge no-op convention against it.
    """
    msgs = x[src] * enorm[:, None]
    return jnp.zeros((n, x.shape[1]), jnp.float32).at[dst].add(msgs)
