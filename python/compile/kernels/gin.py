"""L1 Pallas kernel: fused GIN combine.

GIN's node update is `h_v = MLP((1 + eps) * x_v + sum_{u in N(v)} x_u)`.
The combine `(1+eps)*x + agg` is a bandwidth-bound elementwise op; fusing it
into one VMEM pass avoids materializing the intermediate in HBM. The MLP that
follows uses the fused_linear matmul kernel.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per grid step (feature dim rides along whole).
BROWS = 256


def _gin_kernel(x_ref, a_ref, o_ref, *, eps: float):
    o_ref[...] = (1.0 + eps) * x_ref[...] + a_ref[...]


@functools.partial(jax.jit, static_argnames=("eps", "brows"))
def gin_combine(x, agg, eps: float = 0.0, brows: int = BROWS):
    """`(1 + eps) * x + agg`, tiled over row blocks."""
    assert x.shape == agg.shape, f"{x.shape} vs {agg.shape}"
    m, d = x.shape
    pad = (-m) % brows
    xp = jnp.pad(x.astype(jnp.float32), ((0, pad), (0, 0)))
    ap = jnp.pad(agg.astype(jnp.float32), ((0, pad), (0, 0)))
    mp = m + pad
    out = pl.pallas_call(
        functools.partial(_gin_kernel, eps=eps),
        grid=(mp // brows,),
        in_specs=[
            pl.BlockSpec((brows, d), lambda i: (i, 0)),
            pl.BlockSpec((brows, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((brows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, d), jnp.float32),
        interpret=True,
    )(xp, ap)
    return out[:m]
