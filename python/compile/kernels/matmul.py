"""L1 Pallas kernels: tiled matmul and fused linear layers.

This is the compute hot-spot of every model in the stack (GCN/GIN/LP layers
and the low-rank projection are all `X @ W`-shaped). The kernel is written
for the TPU MXU: a 3-D grid over (M/bm, N/bn, K/bk) tiles, f32 accumulation
into the revisited output block, optional fused bias + ReLU on the final
K step. BlockSpecs express the HBM->VMEM schedule that a CUDA version would
express with threadblocks (DESIGN.md #Hardware-Adaptation).

Kernels are lowered with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, so interpret mode (which lowers to plain HLO)
is the correctness + AOT path; MXU efficiency is *estimated* from the block
shapes (see DESIGN.md #Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default MXU-aligned tile sizes (128x128 systolic array).
BM, BN, BK = 128, 128, 128


def _matmul_kernel(x_ref, w_ref, o_ref, *, nk: int, fuse_bias: bool, relu: bool, b_ref=None):
    """One (i, j, k) grid step: o[i,j] += x[i,k] @ w[k,j] with f32 accumulate.

    The output block is revisited across the K grid dimension (sequential on
    TPU, exact in interpret mode): initialize at k==0, accumulate, and apply
    the fused epilogue (bias add + ReLU) at k==nk-1.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        acc = o_ref[...]
        if fuse_bias:
            acc = acc + b_ref[...]
        if relu:
            acc = jnp.maximum(acc, 0.0)
        o_ref[...] = acc


def _pad_to(x, multiples):
    pads = [(0, (-d) % m) for d, m in zip(x.shape, multiples)]
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x, w, bm: int = BM, bn: int = BN, bk: int = BK):
    """`x[m,k] @ w[k,n]` through the Pallas tiled kernel (f32)."""
    return _linear_impl(x, w, None, relu=False, bm=bm, bn=bn, bk=bk)


@functools.partial(jax.jit, static_argnames=("relu", "bm", "bn", "bk"))
def fused_linear(x, w, b, relu: bool = False, bm: int = BM, bn: int = BN, bk: int = BK):
    """`act(x @ w + b)` with the bias/activation fused into the last K step."""
    return _linear_impl(x, w, b, relu=relu, bm=bm, bn=bn, bk=bk)


def _linear_impl(x, w, b, *, relu: bool, bm: int, bn: int, bk: int):
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"shape mismatch {x.shape} @ {w.shape}"
    xp = _pad_to(x.astype(jnp.float32), (bm, bk))
    wp = _pad_to(w.astype(jnp.float32), (bk, bn))
    mp, kp = xp.shape
    _, np_ = wp.shape
    nk = kp // bk
    grid = (mp // bm, np_ // bn, nk)
    fuse_bias = b is not None
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
    ]
    operands = [xp, wp]
    if fuse_bias:
        bp = _pad_to(b.astype(jnp.float32).reshape(1, -1), (1, bn))
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
        operands.append(bp)

    kernel = functools.partial(_matmul_kernel, nk=nk, fuse_bias=fuse_bias, relu=relu)
    if fuse_bias:
        # Reorder so b_ref lands as the keyword argument.
        def kernel(x_ref, w_ref, b_ref, o_ref):  # noqa: F811
            _matmul_kernel(x_ref, w_ref, o_ref, nk=nk, fuse_bias=True, relu=relu, b_ref=b_ref)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(*operands)
    return out[:m, :n]


def vmem_bytes(bm: int = BM, bn: int = BN, bk: int = BK, fuse_bias: bool = True) -> int:
    """VMEM footprint of one grid step (the #Perf L1 estimate): input tile +
    weight tile + output tile (+ bias tile), all f32."""
    tiles = bm * bk + bk * bn + bm * bn + (bn if fuse_bias else 0)
    return tiles * 4
