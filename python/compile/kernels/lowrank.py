"""L1 Pallas kernel entry for the low-rank projection (paper §4.2).

The client-side projection `X_hat = X @ P` (d -> k, k << d) is exactly the
tiled-matmul workload, with K-dimension blocking mattering most (d = 1433 for
Cora against k as small as 100). This module specializes the shared matmul
kernel with tall-K-friendly tile defaults and documents the VMEM budget used
by the #Perf estimate.
"""

from . import matmul as mm


def project(x, p, bm: int = 128, bn: int = 128, bk: int = 256):
    """`x[n,d] @ p[d,k]` through the Pallas kernel (wider K tiles: the
    projection is K-heavy and N-narrow)."""
    return mm.matmul(x, p, bm=bm, bn=bn, bk=bk)


def vmem_bytes(bm: int = 128, bn: int = 128, bk: int = 256) -> int:
    return mm.vmem_bytes(bm, bn, bk, fuse_bias=False)
