"""L2: JAX model definitions and train/eval steps for the three FGL tasks.

Everything here is *build-time only*: `aot.py` lowers these functions to HLO
text once per shape bucket; the Rust coordinator executes the artifacts via
PJRT and never imports Python.

Models (paper Table 5 backbones):
- NC: 2-layer GCN (`gcn2_*`). FedGCN variants consume pre-aggregated
  features, which the Rust side substitutes into `x` — the model is shared.
- GC: 2-layer GIN with sum pooling (`gin_*`), plain and FedProx steps.
- LP: GCN encoder + dot-product decoder (`lp_*`).

Dense feature transforms go through the L1 Pallas matmul kernel (with a
custom VJP so `jax.grad` also runs through Pallas kernels); the sparse
neighbor aggregation is a gather + segment-sum in jnp, which XLA lowers to
efficient scatter ops and which static edge-padding keeps shape-stable
(pad arcs carry weight 0 and point at the sink node).
"""

import jax
import jax.numpy as jnp

from .kernels import gin as gin_kernel
from .kernels import matmul as mm
from .kernels import ref as kref

# ---------------------------------------------------------------------------
# Kernel backend selection (§Perf, see DESIGN.md).
#
# The Pallas kernels are the *TPU* lowering; on this CPU-PJRT testbed they
# must run under interpret=True, whose per-grid-step interpreter is ~20x
# slower than the identical math expressed directly in jnp (measured 75 ms vs
# 3.2 ms for the cora-bucket train step). Both paths are verified equal by
# python/tests/test_kernels.py, and one pallas-lowered artifact ships in
# every artifact set so the Rust runtime proves the Pallas->HLO->PJRT path
# end-to-end (rust/tests/runtime_numerics.rs).
#
# Backend "reference" (default for CPU artifacts): jnp ops, XLA fuses freely.
# Backend "pallas": interpret-mode Pallas kernels lowered into the HLO.
# ---------------------------------------------------------------------------

_BACKEND = "reference"


def set_backend(name: str) -> None:
    global _BACKEND
    assert name in ("reference", "pallas"), name
    _BACKEND = name


def get_backend() -> str:
    return _BACKEND


@jax.custom_vjp
def _kmatmul_pallas(x, w):
    """Pallas tiled matmul with a Pallas backward pass."""
    return mm.matmul(x, w)


def _kmatmul_fwd(x, w):
    return mm.matmul(x, w), (x, w)


def _kmatmul_bwd(res, g):
    x, w = res
    return mm.matmul(g, w.T), mm.matmul(x.T, g)


_kmatmul_pallas.defvjp(_kmatmul_fwd, _kmatmul_bwd)


def kmatmul(x, w):
    if _BACKEND == "pallas":
        return _kmatmul_pallas(x, w)
    return kref.matmul_ref(x, w)


@jax.custom_vjp
def _kgin_pallas(x, agg):
    """Pallas GIN combine with eps=0 (GIN-0): x + agg."""
    return gin_kernel.gin_combine(x, agg, eps=0.0)


def _kgin_fwd(x, agg):
    return gin_kernel.gin_combine(x, agg, eps=0.0), None


def _kgin_bwd(_, g):
    return g, g


_kgin_pallas.defvjp(_kgin_fwd, _kgin_bwd)


def kgin_combine(x, agg):
    if _BACKEND == "pallas":
        return _kgin_pallas(x, agg)
    return kref.gin_combine_ref(x, agg, 0.0)


# ---------------------------------------------------------------------------
# Shared pieces
# ---------------------------------------------------------------------------


def segment_aggregate(x, src, dst, enorm):
    """out[v] = Σ_e 1[dst[e]=v] · enorm[e] · x[src[e]] (shape-static)."""
    msgs = x[src] * enorm[:, None]
    return jnp.zeros_like(x).at[dst].add(msgs)


def masked_ce(logits, labels, mask):
    """Masked softmax cross-entropy. Returns (mean loss, #correct, #masked)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    cnt = jnp.maximum(mask.sum(), 1.0)
    loss = (mask * nll).sum() / cnt
    correct = (mask * (jnp.argmax(logits, axis=1) == labels)).sum()
    return loss, correct, mask.sum()


def sgd(params, grads, lr):
    return tuple(p - lr * g for p, g in zip(params, grads))


# ---------------------------------------------------------------------------
# Node classification: 2-layer GCN
# ---------------------------------------------------------------------------
# params = (w1[d,h], b1[h], w2[h,c], b2[c])


def gcn2_logits(params, x, src, dst, enorm):
    w1, b1, w2, b2 = params
    # GCN layer: Â (X W) — transform first (d >= h makes this cheaper).
    t = kmatmul(x, w1)
    h = jnp.maximum(segment_aggregate(t, src, dst, enorm) + b1, 0.0)
    t2 = kmatmul(h, w2)
    # Note: second aggregate runs at width c (<h); aggregate-then-bias.
    return segment_aggregate(t2, src, dst, enorm) + b2


def nc_loss(params, x, src, dst, enorm, labels, mask):
    logits = gcn2_logits(params, x, src, dst, enorm)
    loss, correct, cnt = masked_ce(logits, labels, mask)
    return loss, (correct, cnt)


def nc_train_step(w1, b1, w2, b2, x, src, dst, enorm, labels, mask, lr):
    """One local SGD step. Returns (w1', b1', w2', b2', loss, correct, cnt)."""
    params = (w1, b1, w2, b2)
    (loss, (correct, cnt)), grads = jax.value_and_grad(nc_loss, has_aux=True)(
        params, x, src, dst, enorm, labels, mask
    )
    new = sgd(params, grads, lr)
    return (*new, loss, correct, cnt)


def nc_eval_step(w1, b1, w2, b2, x, src, dst, enorm, labels, mask):
    """Forward-only evaluation. Returns (loss, correct, cnt)."""
    loss, (correct, cnt) = nc_loss((w1, b1, w2, b2), x, src, dst, enorm, labels, mask)
    return (loss, correct, cnt)


# ---------------------------------------------------------------------------
# Graph classification: 2-layer GIN (sum aggregation, sum pooling)
# ---------------------------------------------------------------------------
# params = (w1[d,h], b1[h], w2[h,h], b2[h], w3[h,c], b3[c])
# Batch layout: nodes of all graphs concatenated; `gid[n]` maps node -> graph,
# `nmask[n]` zeroes pad nodes before pooling, `gmask[g]` masks pad graphs.


def gin_logits(params, x, src, dst, enorm, gid, nmask, num_graphs):
    w1, b1, w2, b2, w3, b3 = params
    agg = segment_aggregate(x, src, dst, enorm)
    h = kgin_combine(x, agg)
    h = jnp.maximum(kmatmul(h, w1) + b1, 0.0)
    agg2 = segment_aggregate(h, src, dst, enorm)
    h2 = kgin_combine(h, agg2)
    h2 = jnp.maximum(kmatmul(h2, w2) + b2, 0.0)
    h2 = h2 * nmask[:, None]
    pooled = jnp.zeros((num_graphs, h2.shape[1]), jnp.float32).at[gid].add(h2)
    # Mean readout: normalize by each graph's (real-)node count so logits do
    # not scale with graph size (sum readout makes softmax saturate on the
    # larger TU graphs).
    counts = jnp.zeros((num_graphs,), jnp.float32).at[gid].add(nmask)
    pooled = pooled / jnp.maximum(counts, 1.0)[:, None]
    return kmatmul(pooled, w3) + b3


def gc_loss(params, x, src, dst, enorm, gid, nmask, glabels, gmask):
    logits = gin_logits(params, x, src, dst, enorm, gid, nmask, glabels.shape[0])
    loss, correct, cnt = masked_ce(logits, glabels, gmask)
    return loss, (correct, cnt)


def gc_train_step(w1, b1, w2, b2, w3, b3, x, src, dst, enorm, gid, nmask, glabels, gmask, lr):
    params = (w1, b1, w2, b2, w3, b3)
    (loss, (correct, cnt)), grads = jax.value_and_grad(gc_loss, has_aux=True)(
        params, x, src, dst, enorm, gid, nmask, glabels, gmask
    )
    new = sgd(params, grads, lr)
    return (*new, loss, correct, cnt)


def gc_prox_train_step(
    w1, b1, w2, b2, w3, b3,
    g1, c1, g2, c2, g3, c3,
    x, src, dst, enorm, gid, nmask, glabels, gmask, lr, mu,
):
    """FedProx: adds the proximal term μ/2·‖θ − θ_global‖² to the loss."""
    params = (w1, b1, w2, b2, w3, b3)
    glob = (g1, c1, g2, c2, g3, c3)

    def loss_fn(p):
        base, aux = gc_loss(p, x, src, dst, enorm, gid, nmask, glabels, gmask)
        prox = sum(jnp.sum((a - b) ** 2) for a, b in zip(p, glob))
        return base + 0.5 * mu * prox, aux

    (loss, (correct, cnt)), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    new = sgd(params, grads, lr)
    return (*new, loss, correct, cnt)


def gc_eval_step(w1, b1, w2, b2, w3, b3, x, src, dst, enorm, gid, nmask, glabels, gmask):
    loss, (correct, cnt) = gc_loss(
        (w1, b1, w2, b2, w3, b3), x, src, dst, enorm, gid, nmask, glabels, gmask
    )
    return (loss, correct, cnt)


# ---------------------------------------------------------------------------
# Link prediction: GCN encoder + dot-product decoder
# ---------------------------------------------------------------------------
# params = (w1[d,h], b1[h], w2[h,z], b2[z])


def lp_embed(params, x, src, dst, enorm):
    w1, b1, w2, b2 = params
    t = kmatmul(x, w1)
    h = jnp.maximum(segment_aggregate(t, src, dst, enorm) + b1, 0.0)
    t2 = kmatmul(h, w2)
    return segment_aggregate(t2, src, dst, enorm) + b2


def lp_pair_logits(z, eu, ev):
    return jnp.sum(z[eu] * z[ev], axis=1)


def lp_loss(params, x, src, dst, enorm, pos_u, pos_v, neg_u, neg_v, pmask):
    z = lp_embed(params, x, src, dst, enorm)
    pos = lp_pair_logits(z, pos_u, pos_v)
    neg = lp_pair_logits(z, neg_u, neg_v)
    # BCE-with-logits, masked over pad pairs.
    pos_nll = jax.nn.softplus(-pos)
    neg_nll = jax.nn.softplus(neg)
    cnt = jnp.maximum(pmask.sum(), 1.0)
    loss = ((pmask * pos_nll).sum() + (pmask * neg_nll).sum()) / (2.0 * cnt)
    return loss


def lp_train_step(w1, b1, w2, b2, x, src, dst, enorm, pos_u, pos_v, neg_u, neg_v, pmask, lr):
    params = (w1, b1, w2, b2)
    loss, grads = jax.value_and_grad(lp_loss)(
        params, x, src, dst, enorm, pos_u, pos_v, neg_u, neg_v, pmask
    )
    new = sgd(params, grads, lr)
    return (*new, loss)


def lp_score_step(w1, b1, w2, b2, x, src, dst, enorm, eu, ev):
    """Scores (sigmoid probabilities) for candidate pairs — AUC in Rust."""
    z = lp_embed((w1, b1, w2, b2), x, src, dst, enorm)
    return (jax.nn.sigmoid(lp_pair_logits(z, eu, ev)),)
