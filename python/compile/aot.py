"""AOT export: lower every manifest artifact to HLO *text* + manifest.json.

HLO text (not `.serialize()`d protos) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run via `make artifacts`:
    cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import manifest, model

_DTYPES = {"f32": jnp.float32, "i32": jnp.int32}

_KIND_FN = {
    "nc_train": model.nc_train_step,
    "nc_eval": model.nc_eval_step,
    "nc_train_pallas": model.nc_train_step,
    "nc_eval_pallas": model.nc_eval_step,
    "gc_train": model.gc_train_step,
    "gc_prox_train": model.gc_prox_train_step,
    "gc_eval": model.gc_eval_step,
    "lp_train": model.lp_train_step,
    "lp_eval": model.lp_score_step,
}

# Default kernel backend for the bulk of the artifacts. "reference" is the
# CPU-optimal lowering (see model.py); FEDGRAPH_KERNEL_BACKEND=pallas lowers
# EVERYTHING through the interpret-mode Pallas kernels instead (validation
# builds). Artifacts whose kind ends in "_pallas" always use Pallas.
_DEFAULT_BACKEND = os.environ.get("FEDGRAPH_KERNEL_BACKEND", "reference")


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def example_args(art):
    return [
        jax.ShapeDtypeStruct(tuple(spec["shape"]), _DTYPES[spec["dtype"]])
        for spec in art["inputs"]
    ]


def lower_artifact(art) -> str:
    fn = _KIND_FN[art["kind"]]
    backend = "pallas" if art["kind"].endswith("_pallas") else _DEFAULT_BACKEND
    model.set_backend(backend)
    try:
        lowered = jax.jit(fn).lower(*example_args(art))
        return to_hlo_text(lowered)
    finally:
        model.set_backend("reference")


def source_fingerprint() -> str:
    """Hash of the compile package sources — lets `make artifacts` skip
    re-lowering when nothing changed."""
    h = hashlib.sha256()
    pkg = os.path.dirname(__file__)
    for root, _dirs, files in os.walk(pkg):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None, help="substring filter on artifact names")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    arts = manifest.build_artifacts()
    if args.only:
        arts = [a for a in arts if args.only in a["name"]]

    fingerprint = source_fingerprint()
    man_path = os.path.join(args.out, "manifest.json")
    if not args.force and not args.only and os.path.exists(man_path):
        with open(man_path) as f:
            old = json.load(f)
        if old.get("fingerprint") == fingerprint and all(
            os.path.exists(os.path.join(args.out, a["name"] + ".hlo.txt")) for a in arts
        ):
            print(f"artifacts up to date ({len(arts)} entries); skipping")
            return

    t_start = time.time()
    entries = {}
    for i, art in enumerate(arts):
        t0 = time.time()
        text = lower_artifact(art)
        fname = art["name"] + ".hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        entries[art["name"]] = {
            "file": fname,
            "kind": art["kind"],
            "dims": art["dims"],
            "inputs": art["inputs"],
            "outputs": art["outputs"],
        }
        print(
            f"[{i + 1}/{len(arts)}] {art['name']}: {len(text)} chars "
            f"in {time.time() - t0:.2f}s",
            flush=True,
        )

    with open(man_path, "w") as f:
        json.dump(
            {
                "fingerprint": fingerprint,
                "hidden": manifest.HIDDEN,
                "edge_factor": manifest.EDGE_FACTOR,
                "artifacts": entries,
            },
            f,
            indent=1,
        )
    print(f"wrote {len(entries)} artifacts + manifest.json in {time.time() - t_start:.1f}s")


if __name__ == "__main__":
    sys.exit(main())
