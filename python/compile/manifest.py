"""Shape buckets and the artifact manifest — the single source of truth for
what `aot.py` lowers and what the Rust runtime expects.

PJRT executables are compiled for static shapes, so each dataset/task maps to
a ladder of buckets `(n_pad, e_pad)` at its feature/class dims; the Rust side
picks the smallest bucket that fits a client's subgraph or minibatch
(`runtime::manifest::pick_bucket`).

Every artifact is identified by a canonical name:
    {kind}_d{d}_c{c}_n{n}            e.g. nc_train_d1433_c7_n512
(GC adds g{graphs}, LP adds p{pairs}; e_pad is derived from n via EDGE_FACTOR
 and recorded in the manifest entry.)
"""

HIDDEN = 64  # GNN hidden width everywhere (paper's default 2-layer GCN/GIN)
LP_ZDIM = 32  # LP embedding width

# e_pad = EDGE_FACTOR * n_pad covers mean degree ~14 (arxiv) plus self loops.
EDGE_FACTOR = 16


def edges_for(n: int) -> int:
    return EDGE_FACTOR * n


# --- node classification buckets -------------------------------------------
# (dataset tag, feature dim, classes, node-bucket ladder)
NC_DATASETS = [
    ("cora", 1433, 7, [256, 512, 1024, 2048, 4096]),
    ("citeseer", 3703, 6, [256, 512, 1024, 2048, 4096]),
    ("pubmed", 500, 3, [1024, 2048, 4096, 8192, 20480]),
    ("arxiv", 128, 40, [1024, 2048, 4096]),
    ("papers100m", 128, 172, [1024, 2048]),
]

# Low-rank compression ranks for the Fig 7 case study: the projected
# features replace `x`, so the model's input dim becomes the rank.
LOWRANK_RANKS = [100, 200, 400, 800]
LOWRANK_BUCKETS = [256, 512, 1024]
LOWRANK_CLASSES = 7  # cora

# --- graph classification buckets ------------------------------------------
GC_FEAT_DIM = 32
GC_CLASSES = 4  # padded; covers the 2- and 3-class TU datasets
GC_BUCKETS = [(1024, 32), (2048, 32)]  # (nodes, graphs per batch)

# --- link prediction buckets ------------------------------------------------
LP_FEAT_DIM = 64
LP_BUCKETS = [(1024, 2048), (4096, 8192)]  # (nodes, pairs)


def f32(*shape):
    return {"shape": list(shape), "dtype": "f32"}


def i32(*shape):
    return {"shape": list(shape), "dtype": "i32"}


def nc_io(d, c, n, e, train: bool):
    params = [
        ("w1", f32(d, HIDDEN)),
        ("b1", f32(HIDDEN)),
        ("w2", f32(HIDDEN, c)),
        ("b2", f32(c)),
    ]
    data = [
        ("x", f32(n, d)),
        ("src", i32(e)),
        ("dst", i32(e)),
        ("enorm", f32(e)),
        ("labels", i32(n)),
        ("mask", f32(n)),
    ]
    inputs = params + data + ([("lr", f32())] if train else [])
    metrics = [("loss", f32()), ("correct", f32()), ("cnt", f32())]
    outputs = (params if train else []) + metrics
    return inputs, outputs


def gc_io(d, c, n, e, g, kind: str):
    params = [
        ("w1", f32(d, HIDDEN)),
        ("b1", f32(HIDDEN)),
        ("w2", f32(HIDDEN, HIDDEN)),
        ("b2", f32(HIDDEN)),
        ("w3", f32(HIDDEN, c)),
        ("b3", f32(c)),
    ]
    glob = [(f"g{i}", spec) for i, (_, spec) in enumerate(params)]
    data = [
        ("x", f32(n, d)),
        ("src", i32(e)),
        ("dst", i32(e)),
        ("enorm", f32(e)),
        ("gid", i32(n)),
        ("nmask", f32(n)),
        ("glabels", i32(g)),
        ("gmask", f32(g)),
    ]
    metrics = [("loss", f32()), ("correct", f32()), ("cnt", f32())]
    if kind == "gc_train":
        return params + data + [("lr", f32())], params + metrics
    if kind == "gc_prox_train":
        return params + glob + data + [("lr", f32()), ("mu", f32())], params + metrics
    return params + data, metrics  # gc_eval


def lp_io(d, n, e, p, kind: str):
    params = [
        ("w1", f32(d, HIDDEN)),
        ("b1", f32(HIDDEN)),
        ("w2", f32(HIDDEN, LP_ZDIM)),
        ("b2", f32(LP_ZDIM)),
    ]
    graph = [("x", f32(n, d)), ("src", i32(e)), ("dst", i32(e)), ("enorm", f32(e))]
    if kind == "lp_train":
        pairs = [
            ("pos_u", i32(p)),
            ("pos_v", i32(p)),
            ("neg_u", i32(p)),
            ("neg_v", i32(p)),
            ("pmask", f32(p)),
        ]
        return params + graph + pairs + [("lr", f32())], params + [("loss", f32())]
    pairs = [("eu", i32(p)), ("ev", i32(p))]
    return params + graph + pairs, [("scores", f32(p))]


def build_artifacts():
    """Return the full artifact list: dicts with name/kind/dims/inputs/outputs."""
    arts = []

    def add(name, kind, dims, io):
        inputs, outputs = io
        arts.append(
            {
                "name": name,
                "kind": kind,
                "dims": dims,
                "inputs": [{"name": k, **spec} for k, spec in inputs],
                "outputs": [{"name": k, **spec} for k, spec in outputs],
            }
        )

    # NC datasets
    for _tag, d, c, buckets in NC_DATASETS:
        for n in buckets:
            e = edges_for(n)
            dims = {"n": n, "e": e, "d": d, "c": c, "h": HIDDEN}
            add(f"nc_train_d{d}_c{c}_n{n}", "nc_train", dims, nc_io(d, c, n, e, True))
            add(f"nc_eval_d{d}_c{c}_n{n}", "nc_eval", dims, nc_io(d, c, n, e, False))

    # NC low-rank variants (input dim = rank, cora classes)
    for rank in LOWRANK_RANKS:
        for n in LOWRANK_BUCKETS:
            e = edges_for(n)
            c = LOWRANK_CLASSES
            dims = {"n": n, "e": e, "d": rank, "c": c, "h": HIDDEN}
            add(f"nc_train_d{rank}_c{c}_n{n}", "nc_train", dims, nc_io(rank, c, n, e, True))
            add(f"nc_eval_d{rank}_c{c}_n{n}", "nc_eval", dims, nc_io(rank, c, n, e, False))

    # GC buckets
    for n, g in GC_BUCKETS:
        e = edges_for(n)
        d, c = GC_FEAT_DIM, GC_CLASSES
        dims = {"n": n, "e": e, "d": d, "c": c, "h": HIDDEN, "g": g}
        add(f"gc_train_d{d}_c{c}_n{n}_g{g}", "gc_train", dims, gc_io(d, c, n, e, g, "gc_train"))
        add(
            f"gc_prox_train_d{d}_c{c}_n{n}_g{g}",
            "gc_prox_train",
            dims,
            gc_io(d, c, n, e, g, "gc_prox_train"),
        )
        add(f"gc_eval_d{d}_c{c}_n{n}_g{g}", "gc_eval", dims, gc_io(d, c, n, e, g, "gc_eval"))

    # Pallas-backend validation pair (§Perf): the same NC bucket lowered with
    # the interpret-mode Pallas kernels inside the HLO. The Rust runtime test
    # executes it against the reference artifact to prove the
    # Pallas->HLO->PJRT path end-to-end; the runners never pick it (distinct
    # kind).
    for kind, train in [("nc_eval_pallas", False), ("nc_train_pallas", True)]:
        n, d, c = 256, 100, 7
        e = edges_for(n)
        dims = {"n": n, "e": e, "d": d, "c": c, "h": HIDDEN}
        add(f"{kind}_d{d}_c{c}_n{n}", kind, dims, nc_io(d, c, n, e, train))

    # LP buckets
    for n, p in LP_BUCKETS:
        e = edges_for(n)
        d = LP_FEAT_DIM
        dims = {"n": n, "e": e, "d": d, "h": HIDDEN, "z": LP_ZDIM, "p": p}
        add(f"lp_train_d{d}_n{n}_p{p}", "lp_train", dims, lp_io(d, n, e, p, "lp_train"))
        add(f"lp_eval_d{d}_n{n}_p{p}", "lp_eval", dims, lp_io(d, n, e, p, "lp_eval"))

    names = [a["name"] for a in arts]
    assert len(names) == len(set(names)), "duplicate artifact names"
    return arts


if __name__ == "__main__":
    arts = build_artifacts()
    print(f"{len(arts)} artifacts")
    for a in arts:
        print(" ", a["name"])
