#!/usr/bin/env bash
# CI gate: formatting, lints on the federation subsystem (and everything
# else), the engine-free scheduler/sharding tests, and the tier-1 verify
# from ROADMAP.md.
#
# Usage: ./ci.sh            # full gate
#        ./ci.sh --quick    # skip the release build, run tests only
set -euo pipefail
cd "$(dirname "$0")"

if ! command -v cargo >/dev/null 2>&1; then
    echo "ci.sh: cargo not found on PATH — install the Rust toolchain first" >&2
    exit 1
fi

# The crate root lives wherever Cargo.toml is (repo root or rust/).
if [ -f Cargo.toml ]; then
    :
elif [ -f rust/Cargo.toml ]; then
    cd rust
else
    echo "ci.sh: no Cargo.toml found (repo root or rust/)" >&2
    exit 1
fi

echo "==> cargo fmt --check"
cargo fmt --check

echo "==> cargo clippy --all-targets -- -D warnings   (includes federation + coordinator)"
cargo clippy --all-targets -- -D warnings

echo "==> cargo doc --no-deps   (rustdoc gate: module docs + intra-doc links)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "==> engine-free scheduler tests (round policies, staleness, waste ledger)"
cargo test -q --lib federation::

echo "==> engine-free transport tests (wire format, upload codecs, tcp framing, wire ledger)"
cargo test -q --lib transport::

echo "==> engine-free deployment tests (tcp loopback == channel, handshake, config codec)"
cargo test -q --lib federation::runtime::tests::tcp_
cargo test -q --lib federation::deploy::
cargo test -q --lib config::

echo "==> engine-free sharded-aggregation tests (bitwise vs serial)"
cargo test -q --lib coordinator::aggregate::
cargo test -q --lib he::ckks::

echo "==> engine-free sliced-build equivalence tests (worker slice == full-build slice, bitwise)"
cargo test -q --lib coordinator::nc::tests::
cargo test -q --lib coordinator::fedgcn::
cargo test -q --lib util::rng::tests::skip_matches_discarded_draws
cargo test -q --lib graph::subgraph::tests::halo_count_matches_built_view

echo "==> engine-free dataset-format v2 gates (keyed RNG, sliced v2 == full v2 bitwise, gen-work slicing, skip-shim regression)"
cargo test -q --lib util::rng::
cargo test -q --lib graph::generate::
cargo test -q --lib graph::partition::
cargo test -q --lib graph::subgraph::
cargo test -q --lib data::
cargo test -q --lib coordinator::gc::tests::
cargo test -q --lib coordinator::lp::tests::

echo "==> golden dataset checksums (v1 AND v2 pins; first run records the snapshot)"
cargo test -q --test golden_datasets

echo "==> engine-free decode-window tests (per-client referencable bases)"
cargo test -q --lib federation::runtime::tests::sync_decode_window_keeps_at_most_two_bases
cargo test -q --lib federation::runtime::tests::async_decode_window_retains_straggler_base

echo "==> engine-free downlink-codec + rANS gates (SetModelPacked bitwise, raw fallback, entropy stage)"
cargo test -q --lib federation::runtime::tests::pack_compression_is_bitwise_transparent
cargo test -q --lib federation::runtime::tests::pack_over_tcp_matches_none_over_channel_bitwise
cargo test -q --lib federation::runtime::tests::pack_shrinks_measured_wire_payload_and_reports_the_ratio
cargo test -q --lib federation::runtime::tests::packed_downlink_falls_back_to_raw_when_the_base_left_the_window
cargo test -q --lib federation::runtime::tests::rans_entropy_never_inflates_the_packed_wire
cargo test -q --lib transport::serialize::tests::rans_
cargo test -q --test proptests prop_rans
cargo test -q --test proptests prop_pack_rans_codec_roundtrip_is_bitwise

echo "==> engine-free flight-recorder tests (tracing is pure observation; report schema)"
cargo test -q --lib trace::
cargo test -q --lib federation::runtime::tests::traced_run_is_bitwise_identical_and_streams_worker_metrics
cargo test -q --lib monitor::report::tests::report_json_schema_is_stable

echo "==> fault-tolerance gates (chaos harness, checkpoint codec, recovery report schema, chaos suite)"
cargo test -q --lib testing::chaos::
cargo test -q --lib federation::checkpoint::
cargo test -q --lib monitor::report::tests::recovery_notes_fill_the_recovery_section
cargo test -q --test proptests prop_checkpoint_codec_roundtrip_and_corruption
cargo test -q --test federation_chaos

echo "==> durable-orchestration gates (checkpoint store, connect backoff, store proptests)"
cargo test -q --lib federation::store::
cargo test -q --lib transport::tcp::tests::connect_
cargo test -q --test proptests prop_checkpoint_store
cargo test -q --test federation_chaos severed_worker
cargo test -q --test federation_chaos frame_delay_past_heartbeat_is_not_death

if [ "${1:-}" != "--quick" ]; then
    echo "==> cargo build --release   (tier-1, part 1)"
    cargo build --release
fi

echo "==> cargo test -q            (tier-1, part 2)"
cargo test -q

# Multi-process loopback smoke test: a tiny NC run over `--transport tcp`
# with two real `fedgraph worker` subprocesses — once per dataset format
# (v1 replay/skip path, v2 keyed O(assigned) path; the format crosses the
# wire in the config frame, so the workers need no flag). Needs the release
# binary and compiled artifacts (run `make artifacts` first); skipped
# otherwise.
if [ "${1:-}" != "--quick" ]; then
    BIN="target/release/fedgraph"
    if [ -x "$BIN" ] && { [ -f artifacts/manifest.json ] || [ -f ../artifacts/manifest.json ]; }; then
      for SMOKE_FMT in v1 v2; do
        echo "==> multi-process smoke test (tcp loopback, 2 worker subprocesses, dataset-format $SMOKE_FMT)"
        # Randomized port so concurrent CI runs on one host don't collide.
        SMOKE_ADDR="127.0.0.1:$((20000 + RANDOM % 20000))"
        SMOKE_JSON="$(mktemp)"
        SMOKE_TRACE="$(mktemp)"
        "$BIN" worker --connect "$SMOKE_ADDR" --timeout-secs 60 &
        W1=$!
        "$BIN" worker --connect "$SMOKE_ADDR" --timeout-secs 60 &
        W2=$!
        COORD_STATUS=0
        "$BIN" run --task NC --method FedAvg --dataset cora-sim \
            --rounds 2 --trainers 4 --scale 0.15 --local-steps 1 \
            --dataset-format "$SMOKE_FMT" \
            --transport tcp --listen-addr "$SMOKE_ADDR" --workers 2 \
            --json "$SMOKE_JSON" --trace "$SMOKE_TRACE" || COORD_STATUS=$?
        W1_STATUS=0
        W2_STATUS=0
        wait "$W1" || W1_STATUS=$?
        wait "$W2" || W2_STATUS=$?
        if [ "$COORD_STATUS" -ne 0 ] || [ "$W1_STATUS" -ne 0 ] || [ "$W2_STATUS" -ne 0 ]; then
            echo "ci.sh: tcp smoke test failed (coord=$COORD_STATUS w1=$W1_STATUS w2=$W2_STATUS)" >&2
            rm -f "$SMOKE_JSON" "$SMOKE_TRACE"
            exit 1
        fi
        # Sliced-build contract: each worker's reported build counters must
        # cover only its assigned clients (4 trainers round-robin over 2
        # workers -> 2 each), surfaced as coordinator report notes.
        for W in 0 1; do
            if ! grep -q "\"worker${W}_built_clients\": *\"2\"" "$SMOKE_JSON"; then
                echo "ci.sh: worker $W did not report a 2-client sliced build:" >&2
                grep -o "\"worker[01]_[a-z_]*\": *\"[^\"]*\"" "$SMOKE_JSON" >&2 || true
                rm -f "$SMOKE_JSON" "$SMOKE_TRACE"
                exit 1
            fi
        done
        # Observability contract: the traced run wrote a Perfetto-loadable
        # timeline spanning all three processes, and the report carries the
        # streamed per-worker metrics snapshots (RSS / CPU / queue depth).
        if command -v python3 >/dev/null 2>&1; then
            if ! python3 - "$SMOKE_TRACE" "$SMOKE_JSON" <<'PYEOF'
import json, sys
trace = json.load(open(sys.argv[1]))
events = trace["traceEvents"]
assert events, "empty traceEvents"
procs = {e["args"]["name"]: e["pid"] for e in events
         if e.get("ph") == "M" and e.get("name") == "process_name"}
for p in ("coord", "worker0", "worker1"):
    assert p in procs, f"missing process track {p!r} (have {sorted(procs)})"
spans = [e for e in events if e.get("ph") == "X"]
assert spans, "no span events"
for e in spans:
    assert e["ts"] >= 0 and e["dur"] >= 0, f"negative time in {e}"
names = {e["name"] for e in spans}
for n in ("round", "aggregate", "broadcast", "compute"):
    assert n in names, f"missing span {n!r} (have {sorted(names)})"
for w in ("worker0", "worker1"):
    assert any(e["pid"] == procs[w] for e in spans), f"no spans on {w}'s timeline"
counters = {e["name"] for e in events if e.get("ph") == "C"}
assert "rss_mb" in counters, f"no rss counter track (have {sorted(counters)})"
report = json.load(open(sys.argv[2]))
wm = report["worker_metrics"]
for w in ("worker0", "worker1"):
    assert wm.get(w), f"no streamed metrics from {w} (have {sorted(wm)})"
    s = wm[w][0]
    assert s["rss_bytes"] > 0 and s["cpu_seconds"] >= 0, f"bad snapshot {s}"
tracks = {t["track"] for t in report["trace_tracks"]}
assert any(t.startswith("worker0/") for t in tracks), \
    f"no worker-prefixed trace tracks in report (have {sorted(tracks)})"
print(f"trace ok: {len(spans)} spans over {len(procs)} processes, "
      f"{sum(len(v) for v in wm.values())} worker metric samples")
PYEOF
            then
                echo "ci.sh: trace/metrics validation failed" >&2
                rm -f "$SMOKE_JSON" "$SMOKE_TRACE"
                exit 1
            fi
        else
            echo "==> python3 not found; skipping trace-file validation"
        fi
        rm -f "$SMOKE_JSON" "$SMOKE_TRACE"
        echo "==> tcp smoke test ($SMOKE_FMT): coordinator and both workers exited 0; sliced builds covered exactly the assigned clients; merged trace + worker metrics validated"
      done

      # Downlink-codec smoke: the same tiny NC run under `--compression pack
      # --entropy rans`, once traced and once untraced. Asserts the report's
      # up AND down compression ratios went below 1.0 (the negotiated
      # SetModelPacked broadcasts actually shrank the measured wire) and
      # that the measured wire section is byte-identical between the traced
      # and untraced runs — the obs-bytes-exclusion contract, held even
      # while compressed payloads and observation blocks share frames.
      echo "==> multi-process smoke test (tcp loopback, --compression pack --entropy rans)"
      PACK_JSON_PLAIN="$(mktemp)"
      PACK_JSON_TRACED="$(mktemp)"
      PACK_TRACE="$(mktemp)"
      for PACK_MODE in plain traced; do
        SMOKE_ADDR="127.0.0.1:$((20000 + RANDOM % 20000))"
        "$BIN" worker --connect "$SMOKE_ADDR" --timeout-secs 60 &
        W1=$!
        "$BIN" worker --connect "$SMOKE_ADDR" --timeout-secs 60 &
        W2=$!
        COORD_STATUS=0
        if [ "$PACK_MODE" = "traced" ]; then
            "$BIN" run --task NC --method FedAvg --dataset cora-sim \
                --rounds 2 --trainers 4 --scale 0.15 --local-steps 1 \
                --compression pack --entropy rans \
                --transport tcp --listen-addr "$SMOKE_ADDR" --workers 2 \
                --json "$PACK_JSON_TRACED" --trace "$PACK_TRACE" || COORD_STATUS=$?
        else
            "$BIN" run --task NC --method FedAvg --dataset cora-sim \
                --rounds 2 --trainers 4 --scale 0.15 --local-steps 1 \
                --compression pack --entropy rans \
                --transport tcp --listen-addr "$SMOKE_ADDR" --workers 2 \
                --json "$PACK_JSON_PLAIN" || COORD_STATUS=$?
        fi
        W1_STATUS=0
        W2_STATUS=0
        wait "$W1" || W1_STATUS=$?
        wait "$W2" || W2_STATUS=$?
        if [ "$COORD_STATUS" -ne 0 ] || [ "$W1_STATUS" -ne 0 ] || [ "$W2_STATUS" -ne 0 ]; then
            echo "ci.sh: pack tcp smoke ($PACK_MODE) failed (coord=$COORD_STATUS w1=$W1_STATUS w2=$W2_STATUS)" >&2
            rm -f "$PACK_JSON_PLAIN" "$PACK_JSON_TRACED" "$PACK_TRACE"
            exit 1
        fi
      done
      if command -v python3 >/dev/null 2>&1; then
        if ! python3 - "$PACK_JSON_PLAIN" "$PACK_JSON_TRACED" <<'PYEOF'
import json, sys
plain = json.load(open(sys.argv[1]))
traced = json.load(open(sys.argv[2]))
for name, rep in (("plain", plain), ("traced", traced)):
    for key in ("wire_compression_ratio", "wire_compression_ratio_up",
                "wire_compression_ratio_down"):
        r = rep[key]
        assert 0.0 < r < 1.0, f"{name}: {key} = {r}, expected < 1.0"
    train = rep["wire"]["train"]
    assert train["payload_bytes_down"] < train["logical_bytes_down"], \
        f"{name}: broadcasts did not shrink: {train}"
    assert train["payload_bytes_up"] < train["logical_bytes_up"], \
        f"{name}: uploads did not shrink: {train}"
assert plain["wire"] == traced["wire"], (
    "obs bytes leaked into the measured wire ledger:\n"
    f"plain:  {plain['wire']}\ntraced: {traced['wire']}")
print(f"pack smoke ok: ratio_up={plain['wire_compression_ratio_up']:.3f} "
      f"ratio_down={plain['wire_compression_ratio_down']:.3f}, "
      "traced wire ledger identical to untraced")
PYEOF
        then
            echo "ci.sh: pack downlink smoke validation failed" >&2
            rm -f "$PACK_JSON_PLAIN" "$PACK_JSON_TRACED" "$PACK_TRACE"
            exit 1
        fi
      else
        echo "==> python3 not found; skipping pack-smoke JSON validation"
      fi
      rm -f "$PACK_JSON_PLAIN" "$PACK_JSON_TRACED" "$PACK_TRACE"
      echo "==> pack tcp smoke: downlink + uplink ratios < 1.0; obs bytes excluded from the measured ledger"

      # Fault-tolerance chaos smoke (elastic orchestration): the same tiny
      # NC run over 3 worker subprocesses, once undisturbed and once with a
      # worker SIGKILLed mid-run. The coordinator must detect the death,
      # re-assign the dead worker's clients to the survivors, and finish
      # with exit 0 on the *same* final accuracy/loss and the same SimNet
      # byte ledger as the undisturbed run — the sync bitwise-recovery
      # invariant observed end to end across real processes. The report's
      # `recovery` section records the event.
      echo "==> multi-process chaos smoke (tcp loopback, 3 workers, SIGKILL one mid-run)"
      CHAOS_JSON_CLEAN="$(mktemp)"
      CHAOS_JSON_KILLED="$(mktemp)"
      for CHAOS_MODE in clean killed; do
        SMOKE_ADDR="127.0.0.1:$((20000 + RANDOM % 20000))"
        if [ "$CHAOS_MODE" = "killed" ]; then
            CHAOS_JSON="$CHAOS_JSON_KILLED"
        else
            CHAOS_JSON="$CHAOS_JSON_CLEAN"
        fi
        "$BIN" worker --connect "$SMOKE_ADDR" --timeout-secs 60 &
        W1=$!
        "$BIN" worker --connect "$SMOKE_ADDR" --timeout-secs 60 &
        W2=$!
        "$BIN" worker --connect "$SMOKE_ADDR" --timeout-secs 60 &
        W3=$!
        KILLER=""
        if [ "$CHAOS_MODE" = "killed" ]; then
            # The straggler sleeps stretch the run well past this point, so
            # the SIGKILL lands after rendezvous and mid-round.
            ( sleep 1.0; kill -9 "$W3" 2>/dev/null ) &
            KILLER=$!
        fi
        COORD_STATUS=0
        "$BIN" run --task NC --method FedAvg --dataset cora-sim \
            --rounds 6 --trainers 6 --scale 0.15 --local-steps 1 \
            --straggler-ms 500 \
            --transport tcp --listen-addr "$SMOKE_ADDR" --workers 3 \
            --json "$CHAOS_JSON" || COORD_STATUS=$?
        W1_STATUS=0
        W2_STATUS=0
        wait "$W1" || W1_STATUS=$?
        wait "$W2" || W2_STATUS=$?
        if [ "$CHAOS_MODE" = "killed" ]; then
            wait "$W3" 2>/dev/null || true   # SIGKILLed: expected nonzero
            wait "$KILLER" 2>/dev/null || true
        else
            W3_STATUS=0
            wait "$W3" || W3_STATUS=$?
            if [ "$W3_STATUS" -ne 0 ]; then
                echo "ci.sh: chaos smoke clean leg: worker 3 failed ($W3_STATUS)" >&2
                rm -f "$CHAOS_JSON_CLEAN" "$CHAOS_JSON_KILLED"
                exit 1
            fi
        fi
        if [ "$COORD_STATUS" -ne 0 ] || [ "$W1_STATUS" -ne 0 ] || [ "$W2_STATUS" -ne 0 ]; then
            echo "ci.sh: chaos smoke ($CHAOS_MODE) failed (coord=$COORD_STATUS w1=$W1_STATUS w2=$W2_STATUS)" >&2
            rm -f "$CHAOS_JSON_CLEAN" "$CHAOS_JSON_KILLED"
            exit 1
        fi
      done
      if command -v python3 >/dev/null 2>&1; then
        if ! python3 - "$CHAOS_JSON_CLEAN" "$CHAOS_JSON_KILLED" <<'PYEOF'
import json, sys
clean = json.load(open(sys.argv[1]))
killed = json.load(open(sys.argv[2]))
rc, rk = clean["recovery"], killed["recovery"]
assert rc["recoveries"] == 0 and rc["reassigned_clients"] == 0, \
    f"undisturbed run reported recoveries: {rc}"
assert rk["recoveries"] >= 1, f"SIGKILL was not recovered from: {rk}"
assert rk["reassigned_clients"] >= 1, f"no clients were re-assigned: {rk}"
# The sync bitwise-recovery invariant, surfaced in the report: identical
# learning outcome and identical SimNet ledger (recovery traffic is
# wire-measured but never SimNet-charged).
for key in ("final_accuracy", "final_loss", "train_bytes", "pretrain_bytes",
            "train_wasted_bytes"):
    assert clean[key] == killed[key], \
        f"{key} diverged after recovery: {clean[key]} vs {killed[key]}"
print(f"chaos smoke ok: {rk['recoveries']} recovery, "
      f"{rk['reassigned_clients']} clients re-assigned, "
      f"accuracy {killed['final_accuracy']:.4f} identical to undisturbed run")
PYEOF
        then
            echo "ci.sh: chaos smoke validation failed" >&2
            rm -f "$CHAOS_JSON_CLEAN" "$CHAOS_JSON_KILLED"
            exit 1
        fi
      else
        echo "==> python3 not found; skipping chaos-smoke JSON validation"
      fi
      rm -f "$CHAOS_JSON_CLEAN" "$CHAOS_JSON_KILLED"
      echo "==> chaos smoke: SIGKILLed worker recovered; final metrics and SimNet ledger identical to the undisturbed run"

      # Durable-resume smoke (coordinator loss): run with a checkpoint dir,
      # SIGKILL the *coordinator* mid-run, then boot a fresh coordinator with
      # `--resume` from the newest on-disk checkpoint. The resumed run must
      # land on the same final accuracy/loss AND the same SimNet counters as
      # an uninterrupted reference — per mode, across the sync plaintext,
      # pack, and pack+rans wire formats.
      for RESUME_MODE in sync pack rans; do
        case "$RESUME_MODE" in
            sync) MODE_FLAGS="" ;;
            pack) MODE_FLAGS="--compression pack" ;;
            rans) MODE_FLAGS="--compression pack --entropy rans" ;;
        esac
        echo "==> durable-resume smoke (SIGKILL coordinator, --resume; mode $RESUME_MODE)"
        RESUME_CK_DIR="$(mktemp -d)"
        RESUME_JSON_CLEAN="$(mktemp)"
        RESUME_JSON_RESUMED="$(mktemp)"
        # Uninterrupted reference.
        SMOKE_ADDR="127.0.0.1:$((20000 + RANDOM % 20000))"
        "$BIN" worker --connect "$SMOKE_ADDR" --timeout-secs 60 &
        W1=$!
        "$BIN" worker --connect "$SMOKE_ADDR" --timeout-secs 60 &
        W2=$!
        COORD_STATUS=0
        # shellcheck disable=SC2086
        "$BIN" run --task NC --method FedAvg --dataset cora-sim \
            --rounds 8 --trainers 4 --scale 0.15 --local-steps 1 \
            --straggler-ms 400 $MODE_FLAGS \
            --transport tcp --listen-addr "$SMOKE_ADDR" --workers 2 \
            --json "$RESUME_JSON_CLEAN" || COORD_STATUS=$?
        W1_STATUS=0; W2_STATUS=0
        wait "$W1" || W1_STATUS=$?
        wait "$W2" || W2_STATUS=$?
        if [ "$COORD_STATUS" -ne 0 ] || [ "$W1_STATUS" -ne 0 ] || [ "$W2_STATUS" -ne 0 ]; then
            echo "ci.sh: resume smoke reference leg ($RESUME_MODE) failed (coord=$COORD_STATUS w1=$W1_STATUS w2=$W2_STATUS)" >&2
            rm -rf "$RESUME_CK_DIR"; rm -f "$RESUME_JSON_CLEAN" "$RESUME_JSON_RESUMED"
            exit 1
        fi
        # Interrupted leg: checkpoint every 2 rounds, SIGKILL mid-run. The
        # straggler sleeps stretch the run well past the kill point, which
        # itself lands after at least one durable checkpoint commit.
        SMOKE_ADDR="127.0.0.1:$((20000 + RANDOM % 20000))"
        "$BIN" worker --connect "$SMOKE_ADDR" --timeout-secs 60 &
        W1=$!
        "$BIN" worker --connect "$SMOKE_ADDR" --timeout-secs 60 &
        W2=$!
        # shellcheck disable=SC2086
        "$BIN" run --task NC --method FedAvg --dataset cora-sim \
            --rounds 8 --trainers 4 --scale 0.15 --local-steps 1 \
            --straggler-ms 400 $MODE_FLAGS \
            --checkpoint-every 2 --checkpoint-dir "$RESUME_CK_DIR" \
            --transport tcp --listen-addr "$SMOKE_ADDR" --workers 2 &
        COORD=$!
        sleep 2.0
        if ! kill -9 "$COORD" 2>/dev/null; then
            echo "ci.sh: resume smoke ($RESUME_MODE): coordinator finished before the SIGKILL landed" >&2
            rm -rf "$RESUME_CK_DIR"; rm -f "$RESUME_JSON_CLEAN" "$RESUME_JSON_RESUMED"
            exit 1
        fi
        wait "$COORD" 2>/dev/null || true
        # The orphaned workers redial with their session tokens until their
        # retry budget runs out; reap them now.
        kill -9 "$W1" "$W2" 2>/dev/null || true
        wait "$W1" 2>/dev/null || true
        wait "$W2" 2>/dev/null || true
        if ! ls "$RESUME_CK_DIR"/ck-*.fgcp >/dev/null 2>&1; then
            echo "ci.sh: resume smoke ($RESUME_MODE): no durable checkpoint on disk after the kill" >&2
            rm -rf "$RESUME_CK_DIR"; rm -f "$RESUME_JSON_CLEAN" "$RESUME_JSON_RESUMED"
            exit 1
        fi
        # Resume leg: a fresh coordinator + fresh workers boot from the
        # newest valid on-disk checkpoint and drive the remaining rounds.
        SMOKE_ADDR="127.0.0.1:$((20000 + RANDOM % 20000))"
        "$BIN" worker --connect "$SMOKE_ADDR" --timeout-secs 60 &
        W1=$!
        "$BIN" worker --connect "$SMOKE_ADDR" --timeout-secs 60 &
        W2=$!
        COORD_STATUS=0
        # shellcheck disable=SC2086
        "$BIN" run --task NC --method FedAvg --dataset cora-sim \
            --rounds 8 --trainers 4 --scale 0.15 --local-steps 1 \
            --straggler-ms 400 $MODE_FLAGS \
            --checkpoint-every 2 --checkpoint-dir "$RESUME_CK_DIR" \
            --resume "$RESUME_CK_DIR" \
            --transport tcp --listen-addr "$SMOKE_ADDR" --workers 2 \
            --json "$RESUME_JSON_RESUMED" || COORD_STATUS=$?
        W1_STATUS=0; W2_STATUS=0
        wait "$W1" || W1_STATUS=$?
        wait "$W2" || W2_STATUS=$?
        if [ "$COORD_STATUS" -ne 0 ] || [ "$W1_STATUS" -ne 0 ] || [ "$W2_STATUS" -ne 0 ]; then
            echo "ci.sh: resume smoke resumed leg ($RESUME_MODE) failed (coord=$COORD_STATUS w1=$W1_STATUS w2=$W2_STATUS)" >&2
            rm -rf "$RESUME_CK_DIR"; rm -f "$RESUME_JSON_CLEAN" "$RESUME_JSON_RESUMED"
            exit 1
        fi
        if command -v python3 >/dev/null 2>&1; then
            if ! python3 - "$RESUME_JSON_CLEAN" "$RESUME_JSON_RESUMED" <<'PYEOF'
import json, sys
clean = json.load(open(sys.argv[1]))
resumed = json.load(open(sys.argv[2]))
# The resumed run restores SimNet counters from the snapshot and replays the
# remaining rounds: every learning metric and every simulated-network
# counter must equal the uninterrupted reference exactly.
for key in ("final_accuracy", "final_loss", "pretrain_bytes", "train_bytes",
            "pretrain_net_secs", "train_net_secs",
            "pretrain_net_concurrent_secs", "train_net_concurrent_secs",
            "train_wasted_bytes"):
    assert clean[key] == resumed[key], \
        f"{key} diverged across resume: {clean[key]} vs {resumed[key]}"
rec = resumed["recovery"]
assert rec["checkpoint_writes"] >= 1, f"resumed run persisted nothing: {rec}"
assert rec["last_persisted_round"] is not None, f"no persisted round: {rec}"
notes = resumed["notes"]
assert "resumed_from_round" in notes, f"resume note missing (have {sorted(notes)})"
print(f"resume smoke ok: resumed after round {notes['resumed_from_round']}, "
      f"accuracy {resumed['final_accuracy']:.4f} identical to reference, "
      f"{rec['checkpoint_writes']} checkpoint write(s) in the resumed leg")
PYEOF
            then
                echo "ci.sh: durable-resume validation failed ($RESUME_MODE)" >&2
                rm -rf "$RESUME_CK_DIR"; rm -f "$RESUME_JSON_CLEAN" "$RESUME_JSON_RESUMED"
                exit 1
            fi
        else
            echo "==> python3 not found; skipping resume-smoke JSON validation"
        fi
        rm -rf "$RESUME_CK_DIR"
        rm -f "$RESUME_JSON_CLEAN" "$RESUME_JSON_RESUMED"
        echo "==> durable-resume smoke ($RESUME_MODE): SIGKILLed coordinator resumed bitwise from the on-disk checkpoint"
      done

      # Supervisor smoke: `fedgraph launch` spawns the coordinator and the
      # worker fleet, and restarts dead workers as standbys. SIGKILL one
      # worker twice mid-run: the supervisor must respawn each time, the
      # coordinator must recover both deaths, and the whole launch must
      # still exit 0.
      echo "==> supervisor smoke (fedgraph launch, SIGKILL a worker twice)"
      LAUNCH_JSON="$(mktemp)"
      SMOKE_ADDR="127.0.0.1:$((20000 + RANDOM % 20000))"
      "$BIN" launch --workers 3 --listen-addr "$SMOKE_ADDR" --max-restarts 4 \
          --task NC --method FedAvg --dataset cora-sim \
          --rounds 10 --trainers 6 --scale 0.15 --local-steps 1 \
          --straggler-ms 400 --json "$LAUNCH_JSON" &
      LAUNCH=$!
      for KILL_AT in 1.0 1.5; do
        sleep "$KILL_AT"
        # The address is unique to this launch, so the pattern cannot catch
        # workers of a concurrent CI run; lowest pid = oldest worker.
        VICTIM="$(pgrep -f -- "worker --connect $SMOKE_ADDR" | head -n1 || true)"
        if [ -n "$VICTIM" ]; then
            kill -9 "$VICTIM" 2>/dev/null || true
        fi
      done
      LAUNCH_STATUS=0
      wait "$LAUNCH" || LAUNCH_STATUS=$?
      if [ "$LAUNCH_STATUS" -ne 0 ]; then
          echo "ci.sh: supervisor smoke: launch exited $LAUNCH_STATUS" >&2
          rm -f "$LAUNCH_JSON"
          exit 1
      fi
      if command -v python3 >/dev/null 2>&1; then
        if ! python3 - "$LAUNCH_JSON" <<'PYEOF'
import json, sys
report = json.load(open(sys.argv[1]))
rec = report["recovery"]
assert rec["recoveries"] >= 2, \
    f"two SIGKILLed workers must mean >= 2 recoveries: {rec}"
assert rec["reassigned_clients"] >= 1, f"no clients moved: {rec}"
assert report["final_accuracy"] != 0.0, "run produced no result"
print(f"supervisor smoke ok: {rec['recoveries']} recoveries, "
      f"{rec['late_joins']} standby admissions, run completed")
PYEOF
        then
            echo "ci.sh: supervisor smoke validation failed" >&2
            rm -f "$LAUNCH_JSON"
            exit 1
        fi
      else
        echo "==> python3 not found; skipping supervisor-smoke JSON validation"
      fi
      rm -f "$LAUNCH_JSON"
      echo "==> supervisor smoke: both worker kills were respawned and recovered; launch exited 0"
    else
        echo "==> skipping multi-process smoke test (no release binary or artifacts)"
    fi
fi

echo "ci.sh: all green"
