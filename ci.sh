#!/usr/bin/env bash
# CI gate: formatting, lints on the federation subsystem (and everything
# else), the engine-free scheduler/sharding tests, and the tier-1 verify
# from ROADMAP.md.
#
# Usage: ./ci.sh            # full gate
#        ./ci.sh --quick    # skip the release build, run tests only
set -euo pipefail
cd "$(dirname "$0")"

if ! command -v cargo >/dev/null 2>&1; then
    echo "ci.sh: cargo not found on PATH — install the Rust toolchain first" >&2
    exit 1
fi

# The crate root lives wherever Cargo.toml is (repo root or rust/).
if [ -f Cargo.toml ]; then
    :
elif [ -f rust/Cargo.toml ]; then
    cd rust
else
    echo "ci.sh: no Cargo.toml found (repo root or rust/)" >&2
    exit 1
fi

echo "==> cargo fmt --check"
cargo fmt --check

echo "==> cargo clippy --all-targets -- -D warnings   (includes federation + coordinator)"
cargo clippy --all-targets -- -D warnings

echo "==> engine-free scheduler tests (round policies, staleness, waste ledger)"
cargo test -q --lib federation::

echo "==> engine-free sharded-aggregation tests (bitwise vs serial)"
cargo test -q --lib coordinator::aggregate::
cargo test -q --lib he::ckks::

if [ "${1:-}" != "--quick" ]; then
    echo "==> cargo build --release   (tier-1, part 1)"
    cargo build --release
fi

echo "==> cargo test -q            (tier-1, part 2)"
cargo test -q

echo "ci.sh: all green"
