//! Staleness-bounded async federation vs the synchronous barrier, under
//! injected stragglers: the same NC experiment run twice. The sync run pays
//! every round's slowest client; the async run (`federation.mode: async`)
//! flushes after `buffer_size` fresh updates, admits stragglers late with a
//! `1 / (1 + staleness)` weight discount, and rejects uploads more than
//! `max_staleness` broadcasts old (their bytes show up as "waste" in the
//! report). Accuracy typically lands close to sync — the convergence vs
//! wall-clock tradeoff FedGCN frames — while wall clock drops.
//!
//! CLI equivalent:
//!   fedgraph run --task NC --method FedAvg --dataset cora-sim \
//!       --straggler-ms 80 --mode async --max-staleness 2

use fedgraph::config::{FedGraphConfig, FederationMode, Method, Task};
use fedgraph::coordinator::run_fedgraph_with;
use fedgraph::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let scale: f64 =
        std::env::var("FEDGRAPH_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(0.5);
    let rounds: usize =
        std::env::var("FEDGRAPH_BENCH_ROUNDS").ok().and_then(|s| s.parse().ok()).unwrap_or(20);
    let engine = Engine::start(&fedgraph::config::default_artifacts_dir())?;

    let mut cfg = FedGraphConfig::new(Task::NodeClassification, Method::FedAvgNC, "cora-sim")?;
    cfg.n_trainer = 8;
    cfg.global_rounds = rounds;
    cfg.learning_rate = 0.3;
    cfg.local_steps = 2;
    cfg.scale = scale;
    // Rare evals (round 0 + final round): each eval is a rendezvous point
    // that waits for in-flight stragglers, so frequent evals would erode
    // the async advantage this example demonstrates.
    cfg.eval_every = rounds.max(1);
    cfg.federation.straggler_ms = 80.0;
    cfg.federation.max_concurrency = 0;

    // 1. Synchronous barrier: every round waits for the slowest straggler.
    let t0 = std::time::Instant::now();
    let sync = run_fedgraph_with(&cfg, &engine)?;
    let sync_wall = t0.elapsed().as_secs_f64();
    println!(
        "sync  barrier:  {sync_wall:.2}s wall, acc {:.4}, {:.2} MB",
        sync.final_accuracy,
        sync.total_bytes() as f64 / 1e6
    );

    // 2. Staleness-bounded async: flush after half the clients, admit
    //    stragglers up to 2 broadcasts late.
    cfg.federation.mode = FederationMode::Async;
    cfg.federation.max_staleness = 2;
    cfg.federation.buffer_size = 0; // auto: half the participants
    let t1 = std::time::Instant::now();
    let asy = run_fedgraph_with(&cfg, &engine)?;
    let async_wall = t1.elapsed().as_secs_f64();
    let rejected = asy
        .notes
        .iter()
        .find(|(k, _)| k == "stale_rejected")
        .map(|(_, v)| v.clone())
        .unwrap_or_default();
    println!(
        "async bounded:  {async_wall:.2}s wall, acc {:.4}, {:.2} MB ({:.2} MB waste, {} stale)",
        asy.final_accuracy,
        asy.total_bytes() as f64 / 1e6,
        asy.train_wasted_bytes as f64 / 1e6,
        rejected
    );
    println!("speedup: {:.2}x under stragglers", sync_wall / async_wall.max(1e-9));

    engine.shutdown();
    Ok(())
}
