//! End-to-end driver (DESIGN.md §4, deliverable (b)): the full system on a
//! real small workload — FedAvg vs FedGCN on cora-sim and citeseer-sim,
//! logging per-round loss/accuracy curves and the paper-style system report.
//! All three layers compose here: Rust coordinator → PJRT engine →
//! HLO lowered from the JAX/Pallas models.
//!
//! The run is recorded in EXPERIMENTS.md (§End-to-end validation).

use fedgraph::config::{FedGraphConfig, Method, Task};
use fedgraph::coordinator::run_fedgraph_with;
use fedgraph::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let scale: f64 =
        std::env::var("FEDGRAPH_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(1.0);
    let rounds: usize =
        std::env::var("FEDGRAPH_BENCH_ROUNDS").ok().and_then(|s| s.parse().ok()).unwrap_or(100);
    let engine = Engine::start(&fedgraph::config::default_artifacts_dir())?;

    for dataset in ["cora-sim", "citeseer-sim"] {
        for method in [Method::FedAvgNC, Method::FedGcn] {
            let mut cfg = FedGraphConfig::new(Task::NodeClassification, method, dataset)?;
            cfg.n_trainer = 10;
            cfg.global_rounds = rounds;
            cfg.learning_rate = 0.3;
            cfg.local_steps = 3;
            cfg.scale = scale;
            cfg.eval_every = (rounds / 20).max(1);
            let report = run_fedgraph_with(&cfg, &engine)?;
            println!(
                "\n### {dataset} / {} — final acc {:.4}, pre-train {} MB, train {} MB",
                method.name(),
                report.final_accuracy,
                report.pretrain_bytes / 1_000_000,
                report.train_bytes / 1_000_000
            );
            println!("round,loss,accuracy,train_secs");
            for r in &report.rounds {
                if r.round % cfg.eval_every == 0 {
                    println!(
                        "{},{:.4},{:.4},{:.4}",
                        r.round, r.train_loss, r.test_accuracy, r.train_secs
                    );
                }
            }
            println!("{}", report.render());
        }
    }
    engine.shutdown();
    Ok(())
}
