//! Multi-process deployment on loopback: a coordinator with
//! `federation.transport: tcp` plus two workers hosting the trainer actors
//! over real sockets — and a proof that the deployment changes *nothing*
//! numerically: the TCP run's final parameter checksum equals the in-process
//! channel run's, bit for bit.
//!
//! For demonstration the two workers run as threads of this example process
//! (each one executes exactly the `fedgraph worker` code path: connect,
//! `WorkerHello → Assign` handshake, deterministic session rebuild, actor
//! hosting). In a real deployment they are separate processes or machines:
//!
//! ```text
//!   fedgraph run --task NC --method FedAvg --dataset cora-sim \
//!       --transport tcp --listen-addr 0.0.0.0:8791 --workers 2
//!   fedgraph worker --connect <coordinator-host>:8791     # on each machine
//! ```

use std::sync::Arc;
use std::time::Duration;

use fedgraph::config::{FedGraphConfig, Method, Task, TransportKind};
use fedgraph::coordinator::{build_session_sliced, run_fedgraph_with, BuildSlice};
use fedgraph::federation::worker;
use fedgraph::monitor::Monitor;
use fedgraph::runtime::Engine;
use fedgraph::transport::SimNet;

/// Pick a free loopback port (bind 0, read it back, release) so concurrent
/// example runs on one host never collide or cross-connect.
fn free_loopback_addr() -> std::io::Result<String> {
    let probe = std::net::TcpListener::bind("127.0.0.1:0")?;
    Ok(probe.local_addr()?.to_string())
}

fn checksum(report: &fedgraph::Report) -> String {
    report
        .notes
        .iter()
        .find(|(k, _)| k == "param_checksum")
        .map(|(_, v)| v.clone())
        .unwrap_or_default()
}

fn main() -> anyhow::Result<()> {
    let scale: f64 =
        std::env::var("FEDGRAPH_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(0.3);
    let engine = Engine::start(&fedgraph::config::default_artifacts_dir())?;

    let mut cfg = FedGraphConfig::new(Task::NodeClassification, Method::FedAvgNC, "cora-sim")?;
    cfg.n_trainer = 6;
    cfg.global_rounds = 8;
    cfg.local_steps = 2;
    cfg.learning_rate = 0.3;
    cfg.scale = scale;
    cfg.eval_every = 4;

    // 1. Reference: the in-process channel transport.
    let chan = run_fedgraph_with(&cfg, &engine)?;
    println!(
        "channel: acc {:.4}, checksum {}, measured wire {:.2} KB",
        chan.final_accuracy,
        checksum(&chan),
        chan.wire_bytes() as f64 / 1e3
    );

    // 2. The same experiment over TCP with two loopback workers.
    let addr = free_loopback_addr()?;
    cfg.federation.transport = TransportKind::Tcp;
    cfg.federation.listen_addr = addr.clone();
    cfg.federation.workers = 2;
    let mut worker_threads = Vec::new();
    for k in 0..2 {
        // Each worker needs its own engine handle (in a real deployment it
        // is a separate process with its own PJRT runtime).
        let worker_engine = Engine::start(&fedgraph::config::default_artifacts_dir())?;
        let addr = addr.clone();
        worker_threads.push(std::thread::spawn(move || -> anyhow::Result<()> {
            let assignment = worker::connect(&addr, Duration::from_secs(30))?;
            println!("worker {k}: assigned clients {:?}", assignment.clients);
            let monitor =
                Monitor::new(Arc::new(SimNet::with_stage_log(assignment.cfg.network.clone())));
            // Sliced rebuild: this worker materializes only its assigned
            // clients — O(assigned) startup work and memory — yet the run
            // stays bitwise-identical to the in-process reference.
            let slice = BuildSlice::assigned(assignment.n_total, &assignment.clients)?;
            let t0 = std::time::Instant::now();
            let build =
                build_session_sliced(&assignment.cfg, &worker_engine, &monitor, &slice)?;
            let (built, session_bytes) = monitor.session_build();
            let build_secs = t0.elapsed().as_secs_f64();
            println!(
                "worker {k}: built {built}/{} clients ({session_bytes} session bytes, \
                 {build_secs:.2}s)",
                assignment.n_total
            );
            // This process's observation plane (spans ship only when the
            // config has tracing on; metrics snapshots stream regardless).
            let obs = fedgraph::trace::ObsSession {
                recorder: fedgraph::trace::FlightRecorder::new("worker"),
                stats: fedgraph::trace::ProcessStats::new(Duration::from_millis(200)),
                ship_events: assignment.cfg.trace_enabled(),
            };
            worker::serve(
                assignment,
                build,
                monitor.net.clone(),
                worker::BuildStats { session_bytes, build_secs },
                obs,
            )?;
            worker_engine.shutdown();
            Ok(())
        }));
    }
    let tcp = run_fedgraph_with(&cfg, &engine)?;
    for t in worker_threads {
        t.join().expect("worker thread panicked")?;
    }
    println!(
        "tcp:     acc {:.4}, checksum {}, measured wire {:.2} KB (transport={})",
        tcp.final_accuracy,
        checksum(&tcp),
        tcp.wire_bytes() as f64 / 1e3,
        tcp.transport
    );

    assert_eq!(
        checksum(&chan),
        checksum(&tcp),
        "TCP deployment must be bitwise-identical to the in-process run"
    );
    assert_eq!(chan.final_accuracy, tcp.final_accuracy);
    assert_eq!(chan.train_bytes, tcp.train_bytes, "simulated ledgers must agree");
    println!("deployment equivalence holds: channel == tcp, bit for bit");

    engine.shutdown();
    Ok(())
}
