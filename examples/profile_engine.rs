// Engine micro-profile: where does a train-step execute spend time?
use fedgraph::runtime::{Engine, ParamSet, Tensor};
use fedgraph::util::rng::Rng;
use std::time::Instant;

fn main() {
    let eng = Engine::start("artifacts").unwrap();
    for name in ["nc_train_d1433_c7_n512", "nc_train_d1433_c7_n2048", "nc_train_d128_c40_n1024"] {
        let art = eng.manifest.get(name).unwrap().clone();
        let (n, e, d, c, h) = (art.dim("n"), art.dim("e"), art.dim("d"), art.dim("c"), art.dim("h"));
        let mut rng = Rng::seeded(1);
        let params = ParamSet::nc(d, h, c, &mut rng);
        let mut x = vec![0f32; n * d];
        rng.fill_normal_f32(&mut x, 0.0, 1.0);
        let args = || {
            let mut a = params.to_tensors();
            a.push(Tensor::f32(&[n, d], x.clone()));
            a.push(Tensor::i32(&[e], vec![(n - 1) as i32; e]));
            a.push(Tensor::i32(&[e], vec![(n - 1) as i32; e]));
            a.push(Tensor::f32(&[e], vec![0.0; e]));
            a.push(Tensor::i32(&[n], vec![0; n]));
            a.push(Tensor::f32(&[n], vec![1.0; n]));
            a.push(Tensor::scalar_f32(0.1));
            a
        };
        eng.execute(name, args()).unwrap(); // warm
        let s0 = eng.stats();
        let t0 = Instant::now();
        let iters = 30;
        for _ in 0..iters {
            eng.execute(name, args()).unwrap();
        }
        let wall = t0.elapsed().as_secs_f64() / iters as f64;
        let s1 = eng.stats();
        println!(
            "{name}: wall {:.2}ms | execute {:.2}ms h2d {:.2}ms d2h {:.2}ms (arg-clone overhead {:.2}ms)",
            wall * 1e3,
            (s1.execute_secs - s0.execute_secs) / iters as f64 * 1e3,
            (s1.h2d_secs - s0.h2d_secs) / iters as f64 * 1e3,
            (s1.d2h_secs - s0.d2h_secs) / iters as f64 * 1e3,
            (wall - (s1.execute_secs + s1.h2d_secs + s1.d2h_secs - s0.execute_secs - s0.h2d_secs - s0.d2h_secs) / iters as f64) * 1e3
        );
    }
    eng.shutdown();
}
