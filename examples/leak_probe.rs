// §Perf probe: RSS across repeated executes (leak isolation).
use fedgraph::monitor::sysinfo::rss_bytes;
use fedgraph::runtime::{Engine, ParamSet, Tensor};
use fedgraph::util::rng::Rng;

fn main() {
    let eng = Engine::start("artifacts").unwrap();
    let name = "nc_train_d1433_c7_n512";
    let art = eng.manifest.get(name).unwrap().clone();
    let (n, e, d, c, h) = (art.dim("n"), art.dim("e"), art.dim("d"), art.dim("c"), art.dim("h"));
    let mut rng = Rng::seeded(1);
    let params = ParamSet::nc(d, h, c, &mut rng);
    let mut x = vec![0f32; n * d];
    rng.fill_normal_f32(&mut x, 0.0, 1.0);
    for i in 0..100 {
        let mut a = params.to_tensors();
        a.push(Tensor::f32(&[n, d], x.clone()));
        a.push(Tensor::i32(&[e], vec![(n - 1) as i32; e]));
        a.push(Tensor::i32(&[e], vec![(n - 1) as i32; e]));
        a.push(Tensor::f32(&[e], vec![0.0; e]));
        a.push(Tensor::i32(&[n], vec![0; n]));
        a.push(Tensor::f32(&[n], vec![1.0; n]));
        a.push(Tensor::scalar_f32(0.1));
        eng.execute(name, a).unwrap();
        if i % 10 == 0 {
            println!("iter {i}: rss {:.0} MB", rss_bytes() as f64 / 1e6);
        }
    }
    eng.shutdown();
}
