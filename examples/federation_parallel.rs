//! The federation runtime in action: the same NC experiment run three ways —
//! sequential reference (`max_concurrency: 1`), parallel trainers, and
//! parallel trainers under injected stragglers + dropouts — showing that
//! (a) results are bitwise-identical between sequential and parallel runs
//! (compare the `param_checksum` note), (b) parallel rounds absorb straggler
//! delay that serializes the sequential run, and (c) the report's per-client
//! timeline splits round time into compute / wait / transfer. Wall clocks
//! here are end-to-end (dataset generation and warmup included, identical in
//! every variant); see benches/fig15_many_clients.rs for the setup-free
//! overlap metric.

use fedgraph::config::{FedGraphConfig, Method, Task};
use fedgraph::coordinator::run_fedgraph_with;
use fedgraph::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let scale: f64 =
        std::env::var("FEDGRAPH_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(0.5);
    let rounds: usize =
        std::env::var("FEDGRAPH_BENCH_ROUNDS").ok().and_then(|s| s.parse().ok()).unwrap_or(20);
    let engine = Engine::start(&fedgraph::config::default_artifacts_dir())?;

    let mut cfg = FedGraphConfig::new(Task::NodeClassification, Method::FedAvgNC, "cora-sim")?;
    cfg.n_trainer = 8;
    cfg.global_rounds = rounds;
    cfg.learning_rate = 0.3;
    cfg.local_steps = 2;
    cfg.scale = scale;
    cfg.eval_every = (rounds / 4).max(1);

    let checksum = |report: &fedgraph::monitor::report::Report| {
        report
            .notes
            .iter()
            .find(|(k, _)| k == "param_checksum")
            .map(|(_, v)| v.clone())
            .unwrap_or_default()
    };

    // 1. Sequential reference.
    cfg.federation.max_concurrency = 1;
    let t0 = std::time::Instant::now();
    let seq = run_fedgraph_with(&cfg, &engine)?;
    let seq_wall = t0.elapsed().as_secs_f64();
    println!(
        "sequential:       {seq_wall:.2}s wall, acc {:.4}, params {}",
        seq.final_accuracy,
        checksum(&seq)
    );

    // 2. Parallel trainers — identical results, overlapping compute.
    cfg.federation.max_concurrency = 0; // auto
    let t1 = std::time::Instant::now();
    let par = run_fedgraph_with(&cfg, &engine)?;
    let par_wall = t1.elapsed().as_secs_f64();
    println!(
        "parallel:         {par_wall:.2}s wall ({:.2}x), acc {:.4}, params {}",
        seq_wall / par_wall.max(1e-9),
        par.final_accuracy,
        checksum(&par)
    );
    assert_eq!(checksum(&seq), checksum(&par), "parallelism must not change results");

    // 3. Parallel under failures: 30ms stragglers, 10% dropouts.
    cfg.federation.straggler_ms = 30.0;
    cfg.federation.dropout_frac = 0.1;
    let t2 = std::time::Instant::now();
    let rough = run_fedgraph_with(&cfg, &engine)?;
    let rough_wall = t2.elapsed().as_secs_f64();
    println!(
        "parallel+faults:  {rough_wall:.2}s wall, acc {:.4} (stragglers absorbed, dropouts re-weighted)",
        rough.final_accuracy
    );
    println!("\n{}", rough.render());

    engine.shutdown();
    Ok(())
}
