//! Quickstart — the paper's Fig 2 promise: a federated GNN experiment in
//! 10–20 lines. Run with `cargo run --release --example quickstart`.

use fedgraph::config::{FedGraphConfig, Method, Task};

fn main() -> anyhow::Result<()> {
    let mut cfg = FedGraphConfig::new(Task::NodeClassification, Method::FedGcn, "cora-sim")?;
    cfg.n_trainer = 10;
    cfg.global_rounds = 30;
    cfg.learning_rate = 0.3;
    cfg.scale = scale_from_env();
    let report = fedgraph::run_fedgraph(&cfg)?;
    println!("{}", report.render());
    Ok(())
}

/// Examples honor FEDGRAPH_BENCH_SCALE so CI runs stay fast; default is a
/// half-size cora-sim (still the full pipeline).
fn scale_from_env() -> f64 {
    std::env::var("FEDGRAPH_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(0.5)
}
