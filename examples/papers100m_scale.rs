//! Papers100M-sim at scale (paper §5.3 / Fig 12): minibatch federated
//! training over the lazy hash-defined graph with 195 power-law clients.
//!
//! The node count is `FEDGRAPH_PAPERS_SCALE × 1e8` (default 0.01 → 1M nodes
//! for a quick demonstration; set to 1.0 for the full 100M — the lazy
//! representation makes that memory-safe, only sampled blocks materialize).

use fedgraph::config::{FedGraphConfig, Method, Task};
use fedgraph::coordinator::run_fedgraph_with;
use fedgraph::runtime::Engine;
use fedgraph::util::tables::Table;

fn main() -> anyhow::Result<()> {
    let scale: f64 = std::env::var("FEDGRAPH_PAPERS_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.01);
    let rounds: usize =
        std::env::var("FEDGRAPH_BENCH_ROUNDS").ok().and_then(|s| s.parse().ok()).unwrap_or(40);
    let engine = Engine::start(&fedgraph::config::default_artifacts_dir())?;
    let mut table = Table::new(&["batch size", "train s", "accuracy", "peak RSS MB"])
        .with_title(format!("Fig 12 — papers100m-sim, {} nodes, 195 clients", (scale * 1e8) as u64).as_str());
    for batch in [16usize, 32, 64] {
        let mut cfg = FedGraphConfig::new(Task::NodeClassification, Method::FedAvgNC, "papers100m-sim")?;
        cfg.n_trainer = 195;
        cfg.sample_ratio = 0.05; // 9-10 clients per round
        cfg.global_rounds = rounds;
        cfg.batch_size = batch;
        cfg.scale = scale;
        cfg.eval_every = (rounds / 4).max(1);
        let report = run_fedgraph_with(&cfg, &engine)?;
        table.row(&[
            format!("{batch}"),
            format!("{:.2}", report.compute_secs()),
            format!("{:.4}", report.final_accuracy),
            format!("{:.1}", report.peak_rss as f64 / 1e6),
        ]);
    }
    println!("{}", table.render());
    engine.shutdown();
    Ok(())
}
