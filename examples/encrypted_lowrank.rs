//! The §4 case study: communication-efficient federated node classification
//! with low-rank pre-train compression, in all four privacy×compression
//! combinations (plain/HE × full-rank/low-rank). Regenerates the Fig 7
//! trade-off rows at example scale.

use fedgraph::config::{FedGraphConfig, Method, PrivacyMode, Task};
use fedgraph::coordinator::run_fedgraph_with;
use fedgraph::he::CkksParams;
use fedgraph::runtime::Engine;
use fedgraph::util::tables::Table;

fn main() -> anyhow::Result<()> {
    let scale: f64 =
        std::env::var("FEDGRAPH_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(0.5);
    let engine = Engine::start(&fedgraph::config::default_artifacts_dir())?;
    let mut table = Table::new(&[
        "setting", "rank", "pretrain MB", "train MB", "pretrain s", "train s", "accuracy",
    ])
    .with_title("Fig 7 — low-rank pre-train compression on cora-sim (FedGCN)");

    for (he, rank) in [(false, 0), (false, 100), (true, 0), (true, 100)] {
        let mut cfg = FedGraphConfig::new(Task::NodeClassification, Method::FedGcn, "cora-sim")?;
        cfg.n_trainer = 10;
        cfg.global_rounds = 40;
        cfg.learning_rate = 0.3;
        cfg.scale = scale;
        cfg.lowrank_rank = rank;
        if he {
            cfg.privacy = PrivacyMode::He(CkksParams::default_params());
        }
        let report = run_fedgraph_with(&cfg, &engine)?;
        table.row(&[
            if he { "HE" } else { "plaintext" }.to_string(),
            if rank == 0 { "full (1433)".into() } else { format!("{rank}") },
            format!("{:.2}", report.pretrain_bytes as f64 / 1e6),
            format!("{:.2}", report.train_bytes as f64 / 1e6),
            format!("{:.2}", report.pretrain_net_secs),
            format!("{:.2}", report.train_net_secs),
            format!("{:.4}", report.final_accuracy),
        ]);
    }
    println!("{}", table.render());
    engine.shutdown();
    Ok(())
}
