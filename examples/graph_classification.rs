//! Federated graph classification across the GC algorithm family on one
//! TU-style dataset (Fig 8 at example scale): SelfTrain, FedAvg, FedProx,
//! GCFL, GCFL+, GCFL+dWs.

use fedgraph::config::{FedGraphConfig, Method, Task};
use fedgraph::coordinator::run_fedgraph_with;
use fedgraph::runtime::Engine;
use fedgraph::util::tables::Table;

fn main() -> anyhow::Result<()> {
    let engine = Engine::start(&fedgraph::config::default_artifacts_dir())?;
    let mut table = Table::new(&["method", "accuracy", "train s", "comm MB"])
        .with_title("GC algorithms on mutag-sim (10 clients, non-IID beta=1)");
    for method in [
        Method::SelfTrain,
        Method::FedAvgGC,
        Method::FedProx,
        Method::Gcfl,
        Method::GcflPlus,
        Method::GcflPlusDws,
    ] {
        let mut cfg = FedGraphConfig::new(Task::GraphClassification, method, "mutag-sim")?;
        cfg.n_trainer = 10;
        cfg.global_rounds =
            std::env::var("FEDGRAPH_BENCH_ROUNDS").ok().and_then(|s| s.parse().ok()).unwrap_or(60);
        cfg.learning_rate = 0.1;
        cfg.iid_beta = 1.0;
        cfg.eval_every = 10;
        let report = run_fedgraph_with(&cfg, &engine)?;
        table.row(&[
            method.name().to_string(),
            format!("{:.4}", report.final_accuracy),
            format!("{:.2}", report.compute_secs()),
            format!("{:.2}", report.total_bytes() as f64 / 1e6),
        ]);
    }
    println!("{}", table.render());
    engine.shutdown();
    Ok(())
}
