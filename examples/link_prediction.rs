//! Federated link prediction across geographic regions (Fig 10 at example
//! scale): StaticGNN / STFL / FedLink / 4D-FED-GNN+ on the US+BR check-in
//! configuration.

use fedgraph::config::{FedGraphConfig, Method, Task};
use fedgraph::coordinator::run_fedgraph_with;
use fedgraph::runtime::Engine;
use fedgraph::util::tables::Table;

fn main() -> anyhow::Result<()> {
    let scale: f64 =
        std::env::var("FEDGRAPH_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(0.25);
    let engine = Engine::start(&fedgraph::config::default_artifacts_dir())?;
    let mut table = Table::new(&["method", "AUC", "train s", "comm MB"])
        .with_title("LP algorithms on US+BR (one client per region)");
    for method in
        [Method::StaticGnn, Method::Stfl, Method::FedLink, Method::FourDFedGnnPlus]
    {
        let mut cfg = FedGraphConfig::new(Task::LinkPrediction, method, "US+BR")?;
        cfg.global_rounds =
            std::env::var("FEDGRAPH_BENCH_ROUNDS").ok().and_then(|s| s.parse().ok()).unwrap_or(30);
        cfg.local_steps = 2;
        cfg.scale = scale;
        cfg.eval_every = 5;
        let report = run_fedgraph_with(&cfg, &engine)?;
        table.row(&[
            method.name().to_string(),
            format!("{:.4}", report.final_accuracy),
            format!("{:.2}", report.compute_secs()),
            format!("{:.2}", report.total_bytes() as f64 / 1e6),
        ]);
    }
    println!("{}", table.render());
    engine.shutdown();
    Ok(())
}
